"""Benchmark-telemetry subsystem: schema round-trip, gate semantics,
the shared entry contract, the public ``repro.core`` surface, and a
subprocess smoke of ``scripts/bench_gate.py`` against fixture baselines.
"""
import argparse
import json
import os
import subprocess
import sys

import pytest

from repro.bench import (BenchReport, Benchmark, Metric, compare_reports,
                         gate_passes, render_findings, render_trend)
from repro.bench.contract import parse_bench_args

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------- schema

def make_report(**values):
    """A small report with one gated lower-better metric per (name, value)."""
    return BenchReport("toy", meta={"smoke": True}, metrics=[
        Metric(name, v, unit="cycles", direction="lower", slack=0.1)
        for name, v in values.items()])


def test_metric_roundtrip_and_validation():
    m = Metric("a.b", 3.5, unit="s", direction="higher", slack=0.25,
               gate=False, tags={"mesh": "8x8"})
    assert Metric.from_dict(m.to_dict()) == m
    assert Metric.from_dict(json.loads(json.dumps(m.to_dict()))) == m
    # bools normalize to ints so JSON round-trips exactly
    assert Metric("f", True).value == 1
    with pytest.raises(ValueError):
        Metric("bad", 1.0, direction="sideways")
    with pytest.raises(ValueError):
        Metric("bad", float("nan"))
    with pytest.raises(ValueError):
        Metric("bad", 1.0, slack=-0.1)
    with pytest.raises(ValueError):
        Metric("", 1.0)


def test_report_roundtrip(tmp_path):
    rep = BenchReport("toy", meta={"smoke": True, "params": {"rows": 8}},
                      metrics=[Metric("x", 1), Metric("y", 2.5, unit="s")],
                      raw={"free": ["form", 1]})
    assert BenchReport.from_json(rep.to_json()) == rep
    p = tmp_path / "BENCH_toy.json"
    rep.write(str(p))
    assert BenchReport.read(str(p)) == rep
    assert rep.names() == ("x", "y")
    assert rep.metric("x").value == 1
    assert rep.metric("nope") is None
    assert "BENCH toy" in rep.render()


def test_report_rejects_duplicates_and_future_schema():
    with pytest.raises(ValueError):
        BenchReport("toy", metrics=[Metric("x", 1), Metric("x", 2)])
    rep = BenchReport("toy")
    rep.add("x", 1)
    with pytest.raises(ValueError):
        rep.add("x", 2)
    d = rep.to_dict()
    d["schema_version"] = 99
    with pytest.raises(ValueError):
        BenchReport.from_dict(d)


# ------------------------------------------------------------------ gate

def test_gate_within_slack_passes():
    base, fresh = make_report(m=100), make_report(m=105)   # +5% < 10% slack
    f = compare_reports(base, fresh)
    assert [x.kind for x in f] == ["ok"] and gate_passes(f)


def test_gate_regression_beyond_slack_fails():
    base, fresh = make_report(m=100), make_report(m=120)   # +20% > 10%
    (f,) = compare_reports(base, fresh)
    assert f.kind == "regression" and f.fails
    assert not gate_passes([f])
    assert "FAIL" in render_findings("toy", [f])


def test_gate_improvement_direction_is_not_a_failure():
    base, fresh = make_report(m=100), make_report(m=50)    # lower = better
    (f,) = compare_reports(base, fresh)
    assert f.kind == "improvement" and not f.fails
    # and the same drift on a higher-is-better metric fails
    up = BenchReport("toy", metrics=[
        Metric("m", 100, direction="higher", slack=0.1)])
    down = BenchReport("toy", metrics=[
        Metric("m", 50, direction="higher", slack=0.1)])
    (f2,) = compare_reports(up, down)
    assert f2.kind == "regression" and f2.fails


def test_gate_vanished_and_new_metrics():
    base = make_report(kept=1, gone=2)
    fresh = BenchReport("toy", metrics=[
        Metric("kept", 1, direction="lower", slack=0.1),
        Metric("brand_new", 7)])
    f = {x.name: x for x in compare_reports(base, fresh)}
    assert f["gone"].kind == "vanished" and f["gone"].fails
    assert f["brand_new"].kind == "new" and not f["brand_new"].fails
    assert f["kept"].kind == "ok"
    # an *ungated* baseline metric may vanish freely
    base2 = BenchReport("toy", metrics=[Metric("info", 1, gate=False)])
    (f2,) = compare_reports(base2, BenchReport("toy"))
    assert f2.kind == "vanished" and not f2.fails


def test_gate_zero_baseline_uses_absolute_slack():
    base = BenchReport("toy", metrics=[
        Metric("drops", 0, direction="lower", slack=0.0)])
    ok = compare_reports(base, BenchReport("toy", metrics=[
        Metric("drops", 0)]))
    assert gate_passes(ok)
    bad = compare_reports(base, BenchReport("toy", metrics=[
        Metric("drops", 3)]))
    assert not gate_passes(bad)
    # slack interpreted as absolute units when baseline == 0
    base5 = BenchReport("toy", metrics=[
        Metric("drops", 0, direction="lower", slack=5.0)])
    assert gate_passes(compare_reports(base5, BenchReport("toy", metrics=[
        Metric("drops", 3)])))


def test_gate_ungated_metrics_never_fail():
    base = BenchReport("toy", metrics=[
        Metric("wall_s", 1.0, direction="lower", slack=0.0, gate=False)])
    fresh = BenchReport("toy", metrics=[Metric("wall_s", 50.0)])
    (f,) = compare_reports(base, fresh)
    assert f.kind == "info" and not f.fails


def test_gate_slack_scale_loosens():
    base, fresh = make_report(m=100), make_report(m=118)   # +18% > 10%
    assert not gate_passes(compare_reports(base, fresh))
    assert gate_passes(compare_reports(base, fresh, slack_scale=2.0))


def test_gate_area_mismatch_raises():
    with pytest.raises(ValueError):
        compare_reports(make_report(m=1), BenchReport("other"))


def test_trend_render():
    hist = [(lbl, make_report(m=v))
            for lbl, v in (("aaa111", 100), ("bbb222", 90), ("fresh", 80))]
    txt = render_trend(hist)
    assert "aaa111" in txt and "fresh" in txt and "80" in txt
    assert render_trend([]) == "(no history)"


# -------------------------------------------------------------- contract

def _toy_bench():
    def add_args(ap):
        ap.add_argument("--rows", type=int, default=16)
        ap.add_argument("--refs", type=int, default=100)

    def run(args):
        return BenchReport("toy", metrics=[Metric("rows", args.rows)])

    return Benchmark(area="toy", title="toy", add_args=add_args, run=run,
                     smoke={"rows": 4})


def test_contract_smoke_swaps_defaults_but_explicit_flags_win():
    b = _toy_bench()
    assert parse_bench_args(b, []).rows == 16
    assert parse_bench_args(b, ["--smoke"]).rows == 4
    assert parse_bench_args(b, ["--smoke"]).refs == 100   # untouched default
    assert parse_bench_args(b, ["--smoke", "--rows", "9"]).rows == 9


def test_contract_main_writes_out(tmp_path, capsys):
    from repro.bench import bench_main
    out = tmp_path / "BENCH_toy.json"
    rep = bench_main(_toy_bench(), ["--smoke", "--out", str(out)])
    assert rep.meta["smoke"] is True
    assert BenchReport.read(str(out)).metric("rows").value == 4
    assert "BENCH toy" in capsys.readouterr().out


def test_harness_registry_matches_module_areas():
    """benchmarks/run.py --list loads every registered module and asserts
    its BENCH.area matches the registry key (subprocess: several modules
    must manage XLA_FLAGS before jax loads)."""
    out = subprocess.run(
        [sys.executable, os.path.join("benchmarks", "run.py"), "--list"],
        cwd=REPO_ROOT, capture_output=True, text=True, timeout=600,
        env={**os.environ, "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    for area in ("trace", "sweep", "plan", "fig6", "table3", "table4",
                 "roofline"):
        assert area in out.stdout, out.stdout


# ----------------------------------------------------- bench_gate script

GATE = os.path.join("scripts", "bench_gate.py")


def run_gate(*argv):
    return subprocess.run(
        [sys.executable, GATE, *argv], cwd=REPO_ROOT, capture_output=True,
        text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": "src"})


def fixture_dirs(tmp_path, base_value, fresh_value):
    basedir, freshdir = tmp_path / "base", tmp_path / "fresh"
    basedir.mkdir(), freshdir.mkdir()
    make_report(m=base_value).write(str(basedir / "BENCH_toy.json"))
    make_report(m=fresh_value).write(str(freshdir / "BENCH_toy.json"))
    return str(basedir), str(freshdir)


def test_bench_gate_passes_within_slack(tmp_path):
    basedir, freshdir = fixture_dirs(tmp_path, 100, 104)
    out = run_gate("--fresh-dir", freshdir, "--baseline-dir", basedir,
                   "--areas", "toy", "--no-trend")
    assert out.returncode == 0, out.stdout + out.stderr
    assert "bench gate: OK" in out.stdout


def test_bench_gate_fails_on_corrupted_baseline(tmp_path):
    # fresh deterministic value 104 vs a baseline corrupted well below
    # slack: exactly the acceptance drill for the committed BENCH files
    basedir, freshdir = fixture_dirs(tmp_path, 50, 104)
    out = run_gate("--fresh-dir", freshdir, "--baseline-dir", basedir,
                   "--areas", "toy", "--no-trend")
    assert out.returncode == 1, out.stdout + out.stderr
    assert "regression" in out.stdout


def test_bench_gate_missing_baseline_fails_then_update_seeds(tmp_path):
    basedir, freshdir = fixture_dirs(tmp_path, 100, 100)
    os.remove(os.path.join(basedir, "BENCH_toy.json"))
    out = run_gate("--fresh-dir", freshdir, "--baseline-dir", basedir,
                   "--areas", "toy", "--no-trend")
    assert out.returncode == 1
    assert "missing baseline" in out.stderr
    out = run_gate("--fresh-dir", freshdir, "--baseline-dir", basedir,
                   "--areas", "toy", "--no-trend", "--update")
    assert out.returncode == 0, out.stdout + out.stderr
    assert os.path.exists(os.path.join(basedir, "BENCH_toy.json"))


def test_bench_gate_update_refreshes_drifted_baseline(tmp_path):
    basedir, freshdir = fixture_dirs(tmp_path, 50, 104)
    out = run_gate("--fresh-dir", freshdir, "--baseline-dir", basedir,
                   "--areas", "toy", "--no-trend", "--update")
    assert out.returncode == 0, out.stdout + out.stderr
    rep = BenchReport.read(os.path.join(basedir, "BENCH_toy.json"))
    assert rep.metric("m").value == 104


def test_committed_baselines_parse_and_gate_expected_areas():
    """The repo-root BENCH_<area>.json baselines must always parse and
    carry at least one gated metric each (else the CI gate is vacuous)."""
    for area in ("plan", "sweep", "trace"):
        path = os.path.join(REPO_ROOT, f"BENCH_{area}.json")
        assert os.path.exists(path), f"committed baseline missing: {path}"
        rep = BenchReport.read(path)
        assert rep.area == area
        gated = [m for m in rep.metrics if m.gate]
        assert gated, f"{area}: no gated metrics"


# ------------------------------------------------- public core surface

def test_repro_core_public_surface():
    import repro.core as core
    expected = {"SimConfig", "run", "stats_list", "Scenario",
                "compile_plan", "execute_plan", "register", "parse_source",
                "expand_zoo", "make_scenario", "aggregate_stats",
                "network_health"}
    assert expected <= set(core.__all__)
    for name in core.__all__:
        assert getattr(core, name) is not None
    with pytest.raises(AttributeError):
        core.not_a_symbol
    # the lazy façade resolves to the same objects as the submodules
    from repro.core.config import SimConfig
    assert core.SimConfig is SimConfig


def test_network_health_helper():
    from repro.core import aggregate_stats, network_health
    stats = [{"hops": 100, "deflections": 10, "flits_delivered": 20,
              "send_drop": 2, "stray": 1, "cycles": 50, "finished": 1},
             {"hops": 100, "deflections": 0, "flits_delivered": 30,
              "send_drop": 0, "stray": 0, "cycles": 70, "finished": 1}]
    agg = aggregate_stats(stats)
    assert agg["hops"] == 200 and agg["cycles"] == 70 and agg["finished"] == 1
    h = network_health(agg)
    assert h["deflection_rate"] == pytest.approx(10 / 200)
    assert h["hops_per_flit"] == pytest.approx(200 / 50)
    assert h["drops_recovered"] == 2 and h["stray_responses"] == 1
    assert network_health({})["deflection_rate"] == 0.0
