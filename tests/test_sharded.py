"""Sharded NoC sim == single-device (run in a subprocess with 8 host
devices so the main pytest process keeps its single CPU device)."""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, "src")
    import jax
    from repro.core.config import SimConfig
    from repro.core.trace import app_trace
    from repro.core.sim import run
    from repro.core.sharded import ShardedSim

    cfg = SimConfig(rows=8, cols=8, addr_bits=16,
                    centralized_directory=False, dir_layout="home",
                    migrate_threshold=2)
    tr = app_trace(cfg, "mgrid", 30, seed=2)
    ref = run(cfg, tr)
    mesh = jax.make_mesh(%s)
    sh = ShardedSim(cfg, tr, mesh, row_axes=%s, col_axes=("model",))
    got = sh.run(chunk=64)
    print("RESULT " + json.dumps({"match": ref == got,
                                  "cycles": [ref["cycles"], got["cycles"]]}))
""")


def run_case(mesh_expr, row_axes) -> dict:
    code = SCRIPT % (mesh_expr, row_axes)
    out = subprocess.run([sys.executable, "-c", code], cwd=".",
                         capture_output=True, text=True, timeout=900)
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise AssertionError(f"no result\nstdout={out.stdout}\nstderr={out.stderr[-2000:]}")


def test_sharded_single_pod():
    res = run_case('(2, 4), ("data", "model")', '("data",)')
    assert res["match"], res


def test_sharded_multi_pod():
    res = run_case('(2, 2, 2), ("pod", "data", "model")', '("pod", "data")')
    assert res["match"], res


def test_sharded_clamps_max_cycles_to_dense_backend():
    """An unfinished capped run stops at exactly max_cycles even when the
    cap is not a multiple of the host chunk (the tail chunk is clamped),
    so sharded stats match the dense backend bit-for-bit.  A 1x1 mesh on
    the lone CPU device suffices — the clamp is host-loop logic."""
    import jax
    import numpy as np
    from repro.core.config import SimConfig
    from repro.core.sharded import ShardedSim
    from repro.core.sim import run
    from repro.core.trace import app_trace

    cfg = SimConfig(rows=4, cols=4, addr_bits=14,
                    centralized_directory=False, dir_layout="home")
    tr = app_trace(cfg, "mgrid", 25, seed=1)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    got = ShardedSim(cfg, tr, mesh).run(max_cycles=100, chunk=64)
    ref = run(cfg, tr, max_cycles=100)
    assert got["cycles"] == 100 and got["finished"] == 0
    assert got == ref, {k: (ref.get(k), got.get(k)) for k in ref
                        if ref.get(k) != got.get(k)}
