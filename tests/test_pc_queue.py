"""Pending-completion queue + ejection guarantee (the S14 livelock fix).

Covers the contract from three sides:

* **Compatibility** — ``pc_depth=1`` is the paper-faithful single S14
  completion register: bit-identical stats to the pre-queue seed
  semantics (golden dicts recorded before the refactor) on healthy runs.
* **Queue mechanics** — FIFO ordering and capacity at the unit level
  (phase1a serves the head; deliver appends at the tail; a full queue
  parks completions in the ROB and promotes them as it drains).
* **The livelock itself** — the exact ROADMAP wedge (16x16 / matmul /
  seed 0 / refs 20 via the loop-trace generator) runs to completion at
  the default depth with serial/vector bit-parity, including at the
  cycle where the ``pc_depth=1`` model wedges.
"""
import dataclasses
import json

import numpy as np

from repro.core.config import MSG_DA, MSG_DU, SimConfig
from repro.core.ref_serial import SerialSim
from repro.core.sim import VectorSim, run
from repro.core.trace import app_trace, app_trace_loop

# Golden stats captured on the pre-queue seed semantics (single S14
# register).  pc_depth=1 must reproduce them bit-for-bit.
GOLDEN_DISTRIBUTED = json.loads("""
{"req_made": 73, "req_rcvd": 73, "reply_sent": 65, "reply_rcvd": 65,
 "trap": 8, "redirection": 0, "dir_search": 202, "dir_update": 129,
 "mem_req": 137, "migrations": 0, "migrations_done": 0, "l1_hits": 188,
 "l1_misses": 212, "l2_local_hits": 10, "l2_local_misses": 202,
 "wb_sent": 0, "wb_rcvd": 0, "wb_miss": 0, "flits_delivered": 851,
 "deflections": 41, "hops": 2142, "injected": 851, "send_drop": 0,
 "l2_install_drop": 0, "stray": 0, "cycles": 1311, "finished": 1}
""")
GOLDEN_CENTRALIZED = json.loads("""
{"req_made": 94, "req_rcvd": 94, "reply_sent": 83, "reply_rcvd": 83,
 "trap": 11, "redirection": 0, "dir_search": 230, "dir_update": 136,
 "mem_req": 147, "migrations": 0, "migrations_done": 0, "l1_hits": 240,
 "l1_misses": 240, "l2_local_hits": 10, "l2_local_misses": 230,
 "wb_sent": 3, "wb_rcvd": 3, "wb_miss": 0, "flits_delivered": 1003,
 "deflections": 286, "hops": 3346, "injected": 1003, "send_drop": 0,
 "l2_install_drop": 0, "stray": 0, "cycles": 1162, "finished": 1}
""")


def _wedge_cfg(**kw) -> SimConfig:
    return SimConfig(rows=16, cols=16, centralized_directory=False, **kw)


def test_pc_depth_1_bit_identical_to_seed_semantics():
    """The compatibility escape hatch: depth 1 == the pre-queue register."""
    cfg = SimConfig(rows=4, cols=4, addr_bits=14,
                    centralized_directory=False, pc_depth=1)
    got = run(cfg, app_trace(cfg, "equake", 25, seed=1))
    assert got == GOLDEN_DISTRIBUTED, {
        k: (GOLDEN_DISTRIBUTED[k], got.get(k))
        for k in GOLDEN_DISTRIBUTED if got.get(k) != GOLDEN_DISTRIBUTED[k]}

    cfg2 = SimConfig(rows=4, cols=4, addr_bits=14, migrate_threshold=2,
                     pc_depth=1)
    got2 = run(cfg2, app_trace(cfg2, "matmul", 30, seed=1))
    assert got2 == GOLDEN_CENTRALIZED


def test_healthy_run_identical_across_depths():
    """On a run that never saturates S14, the queue is invisible: every
    depth (including the escape hatch) produces the same stats."""
    cfg = SimConfig(rows=4, cols=4, addr_bits=14,
                    centralized_directory=False)
    tr = app_trace(cfg, "equake", 25, seed=1)
    ref = run(dataclasses.replace(cfg, pc_depth=1), tr)
    for depth in (2, 4, 8):
        got = run(dataclasses.replace(cfg, pc_depth=depth), tr)
        assert got == ref, depth


# ---------------------------------------------------------------------------
# queue mechanics at the unit level (serial golden model = the spec)
# ---------------------------------------------------------------------------

def _idle_serial(depth: int, rob_slots: int = 4) -> SerialSim:
    # eject_age_threshold pinned explicitly: these unit tests exercise
    # the age-gating mechanics, which must not depend on the tuned
    # default (0 since the zoo_tune sweep — benchmarks/zoo_thresholds.json)
    cfg = SimConfig(rows=2, cols=2, addr_bits=14, pc_depth=depth,
                    rob_slots=rob_slots, centralized_directory=False,
                    eject_age_threshold=8)
    return SerialSim(cfg, np.full((4, 1), -1, np.int64))


def test_fifo_head_service_order():
    """phase1a serves completions in arrival (FIFO) order."""
    ss = _idle_serial(depth=4)
    # three directory updates queued at node 0: DU(osrc=owner) writes the
    # directory, so service order is observable through dir_loc
    ss.pending[0] = [(MSG_DU, 1, 5, 7), (MSG_DU, 2, 6, 7), (MSG_DU, 3, -1, 7)]
    ss.phase1a(0)
    assert ss.dir_loc[7] == 5 and len(ss.pending[0]) == 2
    ss.phase1a(0)
    assert ss.dir_loc[7] == 6 and len(ss.pending[0]) == 1
    ss.phase1a(0)   # delete: osrc < 0 and dir_loc[7] != src -> unchanged
    assert ss.dir_loc[7] == 6 and not ss.pending[0]


def test_capacity_overflow_parks_in_rob_and_promotes():
    """A completion arriving at a full queue parks in the ROB and is
    promoted (smallest (src, pkt) first) as the queue drains."""
    from repro.core.config import PORT_E
    from repro.core.ref_serial import Flit

    ss = _idle_serial(depth=2)
    cfg = ss.cfg
    ss.pending[0] = [(MSG_DU, 1, -1, 3), (MSG_DU, 1, -1, 4)]   # full
    # an old single-flit DA arrives at node 0 — queue full, age over the
    # threshold: it must still eject (parking path)
    f = Flit(age=cfg.eject_age_threshold + 5, src=3, dst=0, osrc=3,
             typ=MSG_DA, tag=9, pkt=17, fid=0, nfl=1)
    ss.inp[0][PORT_E] = f
    out, eject, defl = ss.phase2(0)
    assert eject is not None and eject[1] is f
    ss.phase3({0: {}, 1: {}, 2: {}, 3: {}},
              {0: eject, 1: None, 2: None, 3: None},
              {0: {}, 1: {}, 2: {}, 3: {}})
    assert len(ss.pending[0]) == 2            # still full
    assert ss.rob[0] == [[3, 17, MSG_DA, 9, 3, 1, 1]]   # parked
    # drain one completion -> the parked DA promotes into the tail
    ss.phase1a(0)
    ss.phase3({n: {} for n in range(4)}, {n: None for n in range(4)},
              {n: {} for n in range(4)})
    assert not ss.rob[0]
    assert ss.pending[0][-1] == (MSG_DA, 3, 3, 9)


def test_full_queue_bars_young_flits_but_not_old():
    """Age-threshold guaranteed ejection: an occupied queue rejects young
    flits (paper-faithful bar) and accepts aged ones."""
    from repro.core.config import PORT_E
    from repro.core.ref_serial import Flit

    ss = _idle_serial(depth=4)
    thr = ss.cfg.eject_age_threshold
    ss.pending[0] = [(MSG_DU, 1, -1, 3)]      # occupied, not full
    young = Flit(age=thr - 1, src=3, dst=0, osrc=3, typ=MSG_DA, tag=9,
                 pkt=1, fid=0, nfl=1)
    ss.inp[0][PORT_E] = young
    _, eject, _ = ss.phase2(0)
    assert eject is None
    young.age = thr                           # now old enough
    _, eject, _ = ss.phase2(0)
    assert eject is not None


def test_depth1_register_still_bars_all_ejection():
    """pc_depth=1 keeps the seed's S14 bar: an occupied register blocks
    ejection regardless of age."""
    from repro.core.config import PORT_E
    from repro.core.ref_serial import Flit

    ss = _idle_serial(depth=1)
    ss.pending[0] = [(MSG_DU, 1, -1, 3)]
    f = Flit(age=10_000, src=3, dst=0, osrc=3, typ=MSG_DA, tag=9,
             pkt=1, fid=0, nfl=1)
    ss.inp[0][PORT_E] = f
    _, eject, _ = ss.phase2(0)
    assert eject is None


# ---------------------------------------------------------------------------
# the ROADMAP wedge itself
# ---------------------------------------------------------------------------

def test_former_wedge_completes_at_default_depth():
    """The exact ROADMAP repro (16x16 / matmul / seed 0 / refs 20 via the
    loop-trace generator) runs to completion instead of aborting, and the
    livelock detector stays quiet while watching it."""
    cfg = _wedge_cfg(max_cycles=200_000)
    assert cfg.pc_depth > 1          # the fix is on by default
    tr = app_trace_loop(cfg, "matmul", 20, 0)
    st = run(cfg, tr, chunk=16)
    assert st["finished"] == 1, st
    assert "aborted" not in st
    # the drain guarantee + retry actually exercised (drops recovered)
    assert st["cycles"] < 50_000


def test_wedge_serial_vector_parity_past_the_wedge_cycle():
    """Serial and vectorized models stay in lockstep THROUGH the cycles
    where the pc_depth=1 model wedges (~cycle 277 the hotspot queue
    freezes; livelock detected ~3.8k): compare FSM/queue state cycle by
    cycle over the critical window, then full-run stats."""
    cfg = _wedge_cfg(max_cycles=200_000)
    tr = app_trace_loop(cfg, "matmul", 20, 0)
    ss = SerialSim(cfg, tr)
    vs = VectorSim(cfg, tr)
    check_at = {250, 300, 500, 1000, 2000}    # brackets the old wedge
    for cyc in range(1, 2001):
        ss.step()
        vs.step()
        if cyc in check_at:
            s = vs.state
            assert np.array_equal(ss.st, np.asarray(s.st)), cyc
            assert np.array_equal(ss.tr_ptr, np.asarray(s.tr_ptr)), cyc
            assert np.array_equal(
                np.array([len(q) for q in ss.sendq]),
                np.asarray(s.q_size)), cyc
            assert np.array_equal(
                np.array([len(p) for p in ss.pending]),
                np.asarray((s.pc[:, :, 0] > 0).sum(axis=1))), cyc
    ref = ss.run()                             # continue to completion
    got = run(cfg, tr, chunk=16)
    assert ref == got, {k: (ref.get(k), got.get(k))
                        for k in set(ref) | set(got)
                        if ref.get(k) != got.get(k)}
    assert ref["finished"] == 1


def test_wedge_still_wedges_at_depth_1():
    """Regression guard for the guard: the pathology is real — with the
    escape hatch the same (cfg, trace) still livelocks and the detector
    still aborts it (tests/test_detectors.py asserts the diagnostics)."""
    cfg = _wedge_cfg(pc_depth=1, livelock_window=256, max_cycles=30_000)
    tr = app_trace_loop(cfg, "matmul", 20, 0)
    st = run(cfg, tr, chunk=16)
    assert st.get("aborted") == "livelock" and st["finished"] == 0


def test_eject_age_threshold_is_a_traced_knob():
    """eject_age_threshold rides as per-scenario traced state: one
    compiled sweep varies it per scenario, matching solo runs; pc_depth
    is structural and must split planner buckets."""
    from repro.core import engine
    from repro.core.sweep import ScenarioSpec, SweepSpec, run_sweep

    cfg = SimConfig(rows=4, cols=4, addr_bits=14,
                    centralized_directory=False)
    spec = SweepSpec(cfg, (
        ScenarioSpec("matmul", 3, 25, eject_age_threshold=0),
        ScenarioSpec("matmul", 3, 25, eject_age_threshold=64),
        ScenarioSpec("matmul", 3, 25),
    ))
    got = run_sweep(spec, chunk=8)
    traces = spec.traces()
    for b, sc in enumerate(spec.scenarios):
        solo = run(sc.resolve_cfg(cfg), traces[b])
        assert got[b] == solo, (b, {
            k: (got[b].get(k), solo.get(k))
            for k in solo if got[b].get(k) != solo.get(k)})

    # knob does not split buckets; pc_depth does
    scs = [engine.make_scenario(cfg, app="matmul", seed=0, refs_per_core=5,
                                eject_age_threshold=t) for t in (0, 8, 64)]
    plan = engine.compile_plan(scs, ndev=1)
    assert len(plan.buckets) == 1
    scs2 = scs + [engine.make_scenario(cfg, app="matmul", seed=0,
                                       refs_per_core=5, pc_depth=2)]
    plan2 = engine.compile_plan(scs2, ndev=1)
    assert len(plan2.buckets) == 2
