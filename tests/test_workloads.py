"""The pluggable workload layer (repro.core.workloads).

Four contracts:

1. **Golden bit-identity** — moving the generators out of
   ``repro.core.trace`` changed nothing: every app/loop/random/model
   output hashes to its pre-refactor digest.
2. **Registry consistency** — validation and dispatch share one parser,
   so every source spec ``valid_app`` accepts is resolvable (the old
   ``valid_app("loop:random")``-accepts / ``resolve_trace``-raises
   disagreement is structurally impossible now), and the grammar's
   error text is generated from the registry.
3. **Pattern properties** — each synthetic pattern's address stream
   realizes its destination pattern through the distributed-directory
   home map: permutation patterns hit exactly the permuted home,
   hotspot concentrates at least the configured fraction on the hot
   homes, the injection rate throttles non-local traffic, and padding
   with the ``-1`` exhaustion sentinel is semantically inert.
4. **Backend invariance** — a zoo slice of every pattern runs to
   completion bit-identically through solo runs and all three planner
   backends (sweep / sharded / composed; subprocess: 8 host devices).
"""
import hashlib
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import workloads as W
from repro.core.config import SimConfig
from repro.core.trace import (TRACE_APPS, app_trace, app_trace_loop,
                              from_model_schedule, random_trace,
                              resolve_trace, stacked_traces, valid_app)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _dig(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


# ---------------------------------------------------------------------------
# 1. golden bit-identity across the trace.py -> workloads move
# ---------------------------------------------------------------------------

#: digests of the pre-refactor generators (captured at the commit that
#: introduced the workloads package, from the then-current trace.py)
GOLDEN = {
    "loop16:matmul": "2ca2ff3bb8e8f400",
    "loop:apsi": "54b1e1d70ff2c79d",
    "loop:equake": "cf6eebe5b1a4abd2",
    "loop:matmul": "b362693beb51f9d8",
    "loop:mgrid": "95ce921255a72c0a",
    "loop:wupwise": "573e8427aba1239a",
    "model": "1ceebaf709bcf8b8",
    "random": "82a6e49edcba00b3",
    "vec16:matmul": "8bdc403a12d295aa",
    "vec:apsi": "a7887f29047dd824",
    "vec:equake": "e88b1495a75f6a6a",
    "vec:matmul": "1490b6bd404e6e5b",
    "vec:mgrid": "7e3118eec85858f4",
    "vec:wupwise": "26b48a0836d5eda7",
}


def test_golden_digests_pin_the_refactor():
    cfg = SimConfig(rows=6, cols=6, centralized_directory=False)
    got = {}
    for app in sorted(TRACE_APPS):
        got[f"vec:{app}"] = _dig(app_trace(cfg, app, 64, 3))
        got[f"loop:{app}"] = _dig(app_trace_loop(cfg, app, 32, 3))
    got["random"] = _dig(random_trace(cfg, 64, 3))
    got["model"] = _dig(from_model_schedule(cfg, 1 << 16, 128, 4, 64, 3))
    cfg16 = SimConfig(rows=16, cols=16)
    got["vec16:matmul"] = _dig(app_trace(cfg16, "matmul", 40, 0))
    got["loop16:matmul"] = _dig(app_trace_loop(cfg16, "matmul", 20, 0))
    assert got == GOLDEN, {k: (got[k], GOLDEN[k])
                           for k in GOLDEN if got[k] != GOLDEN[k]}


def test_resolve_trace_dispatch_matches_direct_calls():
    """The registry dispatch path returns the exact same arrays as the
    direct generator calls (same digests as the golden table)."""
    cfg = SimConfig(rows=6, cols=6, centralized_directory=False)
    assert _dig(resolve_trace(cfg, "matmul", 64, 3)) == GOLDEN["vec:matmul"]
    assert _dig(resolve_trace(cfg, "loop:mgrid", 32, 3)) == GOLDEN["loop:mgrid"]
    assert _dig(resolve_trace(cfg, "random", 64, 3)) == GOLDEN["random"]


# ---------------------------------------------------------------------------
# 2. registry consistency: validation == dispatch
# ---------------------------------------------------------------------------

def _accepted_specs():
    """Every spelling valid_app accepts that the suite exercises: all
    bare registry names, every loop:<app>, and parameterized patterns."""
    specs = list(W.gen_names())
    specs += [f"loop:{a}" for a in TRACE_APPS]
    specs += ["loop:app=equake", "transpose:rate=0.5", "transpose:0.5",
              "bitcomp:rate=1.0", "hotspot:frac=0.8,hot=2",
              "hotspot:0.9", "tornado:rate=0.25", "neighbor:rate=0.1"]
    return specs


def test_every_accepted_name_is_resolvable():
    cfg = SimConfig(rows=4, cols=4, addr_bits=14)
    for spec in _accepted_specs():
        assert valid_app(spec), spec
        tr = resolve_trace(cfg, spec, 8, 0)
        assert tr.shape == (16, 8) and tr.dtype == np.int32, spec
        assert (tr >= 0).all() and (tr < (1 << cfg.addr_bits)).all(), spec


def test_rejected_names_fail_both_ways():
    """valid_app and resolve_trace agree on rejection too — including
    the historical loop:random disagreement (valid_app said yes,
    resolve_trace raised)."""
    cfg = SimConfig(rows=4, cols=4)
    for spec in ("loop:random", "bogus", "hotspot:bad=1", "loop:loop",
                 "transpose:rate=2.0", "transpose:rate=-1",
                 "hotspot:frac=1.5", "hotspot:hot=0", "transpose:0.5,1",
                 "matmul:rate=1.0"):
        assert not valid_app(spec), spec
        with pytest.raises(ValueError):
            resolve_trace(cfg, spec, 8, 0)


def test_scenario_validate_uses_the_registry():
    """engine.Scenario.validate accepts exactly what the registry
    resolves and its error text carries the registry roll-call."""
    from repro.core import engine
    base = SimConfig()
    for spec in _accepted_specs():
        engine.make_scenario(base, 4, 4, app=spec).validate()
    with pytest.raises(ValueError, match="known sources"):
        engine.make_scenario(base, 4, 4, app="bogus").validate()
    with pytest.raises(ValueError, match="random"):
        engine.make_scenario(base, 4, 4, app="loop:random").validate()


def test_grammar_errors_are_specific():
    with pytest.raises(ValueError, match="known sources"):
        W.parse_source("bogus")
    with pytest.raises(ValueError, match="unknown parameter"):
        W.parse_source("hotspot:heat=3")
    with pytest.raises(ValueError, match="duplicate"):
        W.parse_source("hotspot:frac=0.5,frac=0.6")
    with pytest.raises(ValueError, match="positional"):
        W.parse_source("transpose:0.5,0.9")
    with pytest.raises(ValueError, match="cannot parse"):
        W.parse_source("hotspot:hot=two")
    # canonical spec round-trips through the parser
    gen, params = W.parse_source("hotspot:frac=0.8,hot=2")
    assert gen.spec(**params) in ("hotspot:frac=0.8,hot=2",)
    assert W.parse_source(gen.spec(**params))[1] == params


def test_compact_manifest_grammar_carries_source_specs():
    from repro.core import engine
    scs = engine.load_manifest(
        "4x4:hotspot:frac=0.8,hot=2:1:30;8x8:transpose:rate=0.5,"
        "16x16:loop:matmul:0:20")
    assert [(s.cfg.rows, s.app, s.seed, s.refs_per_core) for s in scs] == [
        (4, "hotspot:frac=0.8,hot=2", 1, 30),
        (8, "transpose:rate=0.5", 0, 200),
        (16, "loop:matmul", 0, 20)]
    with pytest.raises(ValueError, match="known sources"):
        engine.load_manifest("4x4:bogus:0")


# ---------------------------------------------------------------------------
# 3. pattern destination-distribution properties
# ---------------------------------------------------------------------------

def _homes(cfg: SimConfig, tr: np.ndarray) -> np.ndarray:
    """Distributed-directory home node of every address (cache.dir_home_v
    semantics: tag % N with tag = addr >> l2_shift)."""
    return (tr >> cfg.cache.l2_shift) % cfg.num_nodes


PERM_PATTERNS = ("transpose", "bitcomp", "tornado", "neighbor")


@pytest.mark.parametrize("name", PERM_PATTERNS)
def test_permutation_patterns_hit_the_permuted_home(name):
    for rows, cols in ((4, 4), (4, 6)):   # square + non-square
        cfg = SimConfig(rows=rows, cols=cols, centralized_directory=False)
        tr = resolve_trace(cfg, name, 40, 0)
        want = W.dst_map(cfg, name)
        assert (_homes(cfg, tr) == want[:, None]).all(), (name, rows, cols)
        # destination maps are permutations of the node set
        assert sorted(want) == list(range(cfg.num_nodes)), name


def test_hotspot_concentrates_on_hot_homes():
    cfg = SimConfig(rows=6, cols=6, centralized_directory=False)
    n = cfg.num_nodes
    frac, hot = 0.7, 2
    tr = resolve_trace(cfg, f"hotspot:frac={frac},hot={hot}", 600, 0)
    homes = _homes(cfg, tr)
    hot_ids = (np.arange(hot) * n) // hot
    hot_share = np.isin(homes, hot_ids).mean()
    # >= the configured fraction (uniform leakage only adds hot hits)
    assert hot_share >= frac, hot_share
    # the uniform remainder still spreads over most of the mesh
    assert len(np.unique(homes)) > n // 2


def test_injection_rate_throttles_remote_traffic():
    cfg = SimConfig(rows=6, cols=6, centralized_directory=False)
    n = cfg.num_nodes
    own = np.arange(n)[:, None]
    for rate in (0.0, 0.3, 1.0):
        tr = resolve_trace(cfg, f"bitcomp:rate={rate}", 1500, 0)
        homes = _homes(cfg, tr)
        remote = (homes != own).mean()   # bitcomp never maps to self
        assert abs(remote - rate) < 0.05, (rate, remote)


def test_patterns_reject_undersized_directory():
    """dir_entries < num_nodes cannot realize one home per destination;
    the generator must refuse instead of silently wrapping the pattern
    (tag % entries would scramble both the homes and the rate
    throttle)."""
    cfg = SimConfig(rows=32, cols=32, addr_bits=14,
                    centralized_directory=False)
    assert cfg.dir_entries < cfg.num_nodes
    for spec in ("transpose", "hotspot:frac=0.5"):
        with pytest.raises(ValueError, match="dir_entries"):
            resolve_trace(cfg, spec, 4, 0)
    # apps are region-based, not home-targeted: they still work
    assert resolve_trace(cfg, "matmul", 4, 0).shape == (1024, 4)


def test_patterns_are_deterministic_and_seed_sensitive():
    cfg = SimConfig(rows=4, cols=4, centralized_directory=False)
    a = resolve_trace(cfg, "hotspot:frac=0.5", 32, 7)
    b = resolve_trace(cfg, "hotspot:frac=0.5", 32, 7)
    c = resolve_trace(cfg, "hotspot:frac=0.5", 32, 8)
    assert (a == b).all()
    assert (a != c).any()


def test_exhaustion_sentinel_padding_is_inert():
    """A pattern trace padded with -1 (stacked_traces) retires exactly
    its own references and matches the unpadded solo run bit-for-bit."""
    from repro.core.sim import run
    cfg = SimConfig(rows=4, cols=4, addr_bits=14,
                    centralized_directory=False)
    stack = stacked_traces(cfg, [("transpose", 0, 6), ("tornado", 1, 10)])
    assert stack.shape == (2, 16, 10)
    assert (stack[0, :, 6:] == -1).all()       # sentinel padding
    assert (stack[0, :, :6] >= 0).all()        # generators never emit -1
    padded = run(cfg, stack[0], chunk=4)
    solo = run(cfg, resolve_trace(cfg, "transpose", 6, 0), chunk=4)
    assert padded == solo


# ---------------------------------------------------------------------------
# 4. backend invariance on a zoo slice (subprocess: 8 host devices)
# ---------------------------------------------------------------------------

def test_patterns_bit_exact_across_backends():
    """Every synthetic pattern of the patterns-tiny zoo slice completes
    and is bit-identical through solo run / forced sweep / forced
    composed / forced sharded on an 8-device host mesh."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import sys, json
        sys.path.insert(0, "src")
        from repro.core import engine
        from repro.core.sim import run
        from repro.core.workloads import resolve_trace
        from repro.core.zoo import expand_zoo

        scs = expand_zoo("patterns-tiny:refs=8,seeds=0")
        solo = []
        for sc in scs:
            tr = resolve_trace(sc.cfg, sc.app, sc.refs_per_core, sc.seed)
            solo.append(run(sc.cfg, tr, chunk=4))
        sweep = engine.plan_and_run(scs, chunk=4, force_backend="sweep")
        comp = engine.plan_and_run(scs, chunk=4, force_backend="composed")
        # sharded takes batch-1 buckets only: run each pattern solo
        shard = [engine.plan_and_run([sc], chunk=4,
                                     force_backend="sharded")[0]
                 for sc in scs]
        print("RESULT " + json.dumps({
            "finished": all(s["finished"] for s in solo),
            "sweep_match": sweep == solo,
            "composed_match": comp == solo,
            "sharded_match": shard == solo,
            "apps": [sc.app for sc in scs]}))
    """)
    out = subprocess.run([sys.executable, "-c", code], cwd=REPO_ROOT,
                         capture_output=True, text=True, timeout=900)
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            res = json.loads(line[len("RESULT "):])
            assert len(res["apps"]) == 5 and res["finished"], res
            assert res["sweep_match"], res
            assert res["composed_match"], res
            assert res["sharded_match"], res
            return
    raise AssertionError(
        f"no result\nstdout={out.stdout}\nstderr={out.stderr[-2000:]}")
