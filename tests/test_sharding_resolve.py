"""Spec resolution: alias filtering, Alt fallback, divisibility dropping;
plus the dry-run's collective-bytes HLO parser."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models.params import Alt
from repro.parallel.sharding import resolve_pspec


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_alias_filtering(mesh):
    got = resolve_pspec(P(("pod", "data"), None), mesh, (8, 4))
    assert got == P(("data",), None)


def test_alt_picks_first_fitting():
    mesh = jax.make_mesh((1,), ("model",))
    # fake a 16-wide model axis via abstract check against divisibility:
    spec = Alt(P(None, "model", None), P("model", None, None))
    # heads=14 won't divide 1 -> everything divides a size-1 axis; use shape
    got = resolve_pspec(spec, mesh, (64, 14, 8))
    assert got == P(None, "model", None)


def test_drop_nondivisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    got = resolve_pspec(P("data", "model"), mesh, (7, 5))
    assert got == P("data", "model")   # size-1 axes always divide


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
      %ar = f32[128,256]{1,0} all-reduce(%x), channel_id=1, replica_groups=[4,2]<=[8]
      ROOT %ar2 = f32[16]{0} all-reduce(%y), channel_id=2
      %ag = bf16[64]{0} all-gather(%y), dimensions={0}
      %ags = (bf16[8]{0}, bf16[64]{0}) all-gather-start(%q), dimensions={0}
      %cp = s32[16,4]{1,0} collective-permute(%z)
      %rs = f32[8]{0} reduce-scatter(%w)
      %aa = f32[4,4]{1,0} all-to-all(%v)
      %fus = f32[9]{0} fusion(%all-reduce), kind=kLoop
    """
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 128 * 256 * 4 + 16 * 4
    assert got["all-gather"] == 64 * 2 + 64 * 2
    assert got["collective-permute"] == 16 * 4 * 4
    assert got["reduce-scatter"] == 8 * 4
    assert got["all-to-all"] == 16 * 4


class _FakeMesh:
    """Spec-resolution shim: the resolver only reads .shape/.axis_names,
    so production-size meshes can be modelled without 512 devices."""

    def __init__(self, axes, sizes):
        self.axis_names = tuple(axes)
        self.shape = dict(zip(axes, sizes))


def test_param_specs_resolve_on_production_meshes():
    """Every arch's param/cache specs resolve with no divisibility errors on
    both production meshes (the cheap core of the dry-run guarantee)."""
    from repro.configs import registry
    from repro.models import api
    from repro.parallel.sharding import tree_pspecs_resolved, _axis_size

    for axes, shape in ((("data", "model"), (16, 16)),
                        (("pod", "data", "model"), (2, 16, 16))):
        mesh = _FakeMesh(axes, shape)
        for arch in registry.ARCH_IDS:
            cfg = registry.get(arch)
            a = api.abstract_params(cfg)
            specs = tree_pspecs_resolved(api.param_pspecs(cfg), mesh, a)
            flat_s = jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, P))
            flat_a = jax.tree_util.tree_leaves(a)
            for s, arr in zip(flat_s, flat_a):
                for dim, entry in zip(arr.shape, s):
                    assert dim % _axis_size(mesh, entry) == 0
