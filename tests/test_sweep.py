"""Batched sweep engine vs per-scenario solo runs (bit-exact).

``run_sweep`` executes B scenarios in one vmapped compiled loop; every
test here asserts its per-scenario stats are *identical* — every counter,
the cycle count, and the finished flag — to what a solo
:func:`repro.core.sim.run` produces for the same scenario.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

import numpy as np
import pytest

from repro.core.config import SimConfig
from repro.core.sim import run
from repro.core.sweep import ScenarioSpec, SweepSpec, run_sweep
from repro.core.trace import app_trace, random_trace, stacked_traces


def solo(cfg: SimConfig, sc: ScenarioSpec):
    rc = sc.resolve_cfg(cfg)
    tr = (random_trace(rc, sc.refs_per_core, sc.seed) if sc.app == "random"
          else app_trace(rc, sc.app, sc.refs_per_core, sc.seed))
    return run(rc, tr)


def assert_matches_solo(cfg: SimConfig, spec: SweepSpec, got) -> None:
    assert len(got) == spec.size
    for sc, g in zip(spec.scenarios, got):
        ref = solo(cfg, sc)
        assert ref == g, (sc, {k: (ref[k], g.get(k)) for k in ref
                               if ref[k] != g.get(k)})


def test_sweep_apps_by_seeds_bit_exact():
    """8 scenarios (4 apps x 2 seeds) in one jitted batch == 8 solo runs."""
    cfg = SimConfig(rows=4, cols=4, addr_bits=14, migrate_threshold=2,
                    centralized_directory=False)
    spec = SweepSpec.cross(cfg, ["matmul", "equake", "mgrid", "random"],
                           [1, 7], refs_per_core=25)
    assert spec.size == 8
    assert_matches_solo(cfg, spec, run_sweep(spec))


def test_sweep_mixed_termination():
    """Scenarios of different lengths coexist: early finishers freeze
    bit-exactly while stragglers keep stepping (chunked loop included)."""
    cfg = SimConfig(rows=4, cols=4, addr_bits=14, centralized_directory=False)
    spec = SweepSpec(cfg, (
        ScenarioSpec("wupwise", 5, refs_per_core=8),
        ScenarioSpec("wupwise", 5, refs_per_core=40),
        ScenarioSpec("apsi", 2, refs_per_core=15),
    ))
    got = run_sweep(spec, chunk=4)
    assert got[0]["cycles"] < got[1]["cycles"]
    assert all(g["finished"] for g in got)
    assert_matches_solo(cfg, spec, got)


def test_sweep_policy_knobs():
    """Per-scenario traced knobs (migration on/off, threshold, directory
    placement) match solo runs whose *static* config carries the knob."""
    cfg = SimConfig(rows=4, cols=4, addr_bits=14, centralized_directory=False)
    spec = SweepSpec(cfg, (
        ScenarioSpec("matmul", 3, 25, migration_enabled=False),
        ScenarioSpec("matmul", 3, 25, migrate_threshold=1),
        ScenarioSpec("matmul", 3, 25, centralized_directory=True),
        ScenarioSpec("matmul", 3, 25),
    ))
    got = run_sweep(spec)
    # the knobs must actually change behaviour, not just be carried along
    assert len({tuple(sorted(g.items())) for g in got}) > 1
    assert_matches_solo(cfg, spec, got)


def test_sweep_chunked_equals_unchunked():
    cfg = SimConfig(rows=4, cols=4, addr_bits=14, centralized_directory=False)
    spec = SweepSpec.cross(cfg, ["mgrid"], [0, 3], refs_per_core=20)
    assert run_sweep(spec, chunk=1) == run_sweep(spec, chunk=8)


def test_stacked_traces_padding():
    cfg = SimConfig(rows=4, cols=4, addr_bits=14)
    trs = stacked_traces(cfg, [("matmul", 0, 10), ("matmul", 0, 30)])
    assert trs.shape == (2, cfg.num_nodes, 30)
    assert np.all(trs[0, :, 10:] == -1)
    assert np.array_equal(trs[0, :, :10], app_trace(cfg, "matmul", 10, 0))


def test_sweep_rejects_centralized_with_home_layout():
    cfg = SimConfig(rows=4, cols=4, addr_bits=14,
                    centralized_directory=False, dir_layout="home")
    spec = SweepSpec(cfg, (ScenarioSpec("matmul", 0, 10,
                                        centralized_directory=True),))
    with pytest.raises(ValueError):
        run_sweep(spec)


def test_sweep_sharded_over_host_devices():
    """run_sweep shards the scenario axis over jax devices; results must
    stay bit-identical to solo runs (subprocess so the main pytest
    process keeps its single CPU device)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import sys, json
        sys.path.insert(0, "src")
        from repro.core.config import SimConfig
        from repro.core.sim import run
        from repro.core.sweep import SweepSpec, run_sweep
        from repro.core.trace import app_trace

        cfg = SimConfig(rows=4, cols=4, addr_bits=14, migrate_threshold=2,
                        centralized_directory=False)
        spec = SweepSpec.cross(cfg, ["matmul", "equake"], [1, 7], 20)
        got = run_sweep(spec, chunk=4)
        ref = [run(cfg, app_trace(cfg, sc.app, 20, sc.seed))
               for sc in spec.scenarios]
        print("RESULT " + json.dumps({"match": got == ref}))
    """)
    out = subprocess.run([sys.executable, "-c", code], cwd=REPO_ROOT,
                         capture_output=True, text=True, timeout=900)
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            assert json.loads(line[len("RESULT "):])["match"]
            return
    raise AssertionError(
        f"no result\nstdout={out.stdout}\nstderr={out.stderr[-2000:]}")


def test_sweep_indivisible_batch_pads_across_devices():
    """3 scenarios on 2 devices: run_sweep pads the batch to 4 internally
    so both devices are used; results stay bit-identical to solo runs
    (subprocess so the main pytest process keeps its single device)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import sys, json
        sys.path.insert(0, "src")
        from repro.core.config import SimConfig
        from repro.core.sim import run
        from repro.core.sweep import SweepSpec, run_sweep
        from repro.core.trace import app_trace

        cfg = SimConfig(rows=4, cols=4, addr_bits=14, migrate_threshold=2,
                        centralized_directory=False)
        spec = SweepSpec.cross(cfg, ["matmul", "equake", "mgrid"], [3], 15)
        got = run_sweep(spec, chunk=4)
        ref = [run(cfg, app_trace(cfg, sc.app, 15, sc.seed))
               for sc in spec.scenarios]
        print("RESULT " + json.dumps({"n": len(got), "match": got == ref}))
    """)
    out = subprocess.run([sys.executable, "-c", code], cwd=REPO_ROOT,
                         capture_output=True, text=True, timeout=900)
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            res = json.loads(line[len("RESULT "):])
            assert res["n"] == 3 and res["match"], res
            return
    raise AssertionError(
        f"no result\nstdout={out.stdout}\nstderr={out.stderr[-2000:]}")


def test_solo_run_unchanged_by_batch_support():
    """A 2-D trace still drives the classic solo path (regression guard
    for the batch-axis threading through init_state/_run_jit)."""
    cfg = SimConfig(rows=4, cols=4, addr_bits=14, migrate_threshold=1,
                    centralized_directory=False)
    tr = app_trace(cfg, "matmul", 25, 3)
    a = run(cfg, tr)
    b = run(dataclasses.replace(cfg, migration_enabled=False), tr)
    assert a["finished"] and b["finished"]
    assert a["migrations"] > 0 and b["migrations"] == 0
    assert a != b  # knob still has effect on the solo path
