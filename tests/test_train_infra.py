"""Training substrate: optimizer, data determinism, checkpoint/restart."""
import shutil

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.config import ModelConfig
from repro.train.checkpoint import latest, restore, save
from repro.train.data import DataConfig, DataSource, DataState
from repro.train.loop import LoopConfig, Trainer
from repro.train.optim import OptConfig


def tiny_cfg() -> ModelConfig:
    return ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
                       max_seq=64, remat=False)


def test_data_deterministic_and_resumable():
    cfg = tiny_cfg()
    src = DataSource(DataConfig(batch=2, seq=16, seed=5), cfg)
    a = src.batch_at(DataState(7))
    b = src.batch_at(DataState(7))
    c = src.batch_at(DataState(8))
    assert np.array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_loss_decreases():
    cfg = tiny_cfg()
    tr = Trainer(cfg, OptConfig(lr=1e-3, warmup=5, total_steps=40),
                 DataConfig(batch=2, seq=32, seed=1),
                 LoopConfig(steps=40, ckpt_dir="/tmp/rt_ck1", resume=False,
                            ckpt_every=1000, log_every=1000))
    shutil.rmtree("/tmp/rt_ck1", ignore_errors=True)
    out = tr.run()
    first = tr.metrics_log[0]["loss"]
    assert out["final_loss"] < first, (first, out)


def test_checkpoint_restart_exact():
    """Interrupted-then-resumed == uninterrupted (fault tolerance)."""
    cfg = tiny_cfg()
    opt = OptConfig(lr=1e-3, warmup=2, total_steps=12)
    data = DataConfig(batch=2, seq=16, seed=2)

    shutil.rmtree("/tmp/rt_ckA", ignore_errors=True)
    t1 = Trainer(cfg, opt, data, LoopConfig(
        steps=12, ckpt_dir="/tmp/rt_ckA", ckpt_every=100, resume=False,
        log_every=1000))
    t1.run()
    ref = jax.tree.map(np.asarray, t1.params)

    shutil.rmtree("/tmp/rt_ckB", ignore_errors=True)
    t2 = Trainer(cfg, opt, data, LoopConfig(
        steps=6, ckpt_dir="/tmp/rt_ckB", ckpt_every=6, resume=False,
        log_every=1000))
    t2.run()   # stops at step 6 ("preemption"), checkpoint written
    t3 = Trainer(cfg, opt, data, LoopConfig(
        steps=12, ckpt_dir="/tmp/rt_ckB", ckpt_every=100, resume=True,
        log_every=1000))
    t3.run()
    got = jax.tree.map(np.asarray, t3.params)

    flat_r = jax.tree_util.tree_leaves(ref)
    flat_g = jax.tree_util.tree_leaves(got)
    for r, g in zip(flat_r, flat_g):
        np.testing.assert_allclose(r, g, rtol=0, atol=0)


def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.ones(4, np.int32)}}
    for step in (1, 2, 3, 4, 5):
        save(str(tmp_path), step, tree, data_state={"step": step},
             cfg_hash="x", keep=3)
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 3 and kept[-1] == "step_00000005"
    got, manifest = restore(latest(str(tmp_path)), tree)
    assert manifest["step"] == 5
    np.testing.assert_array_equal(got["a"], tree["a"])
    np.testing.assert_array_equal(got["b"]["c"], tree["b"]["c"])


def test_serve_drains_and_is_greedy_consistent():
    from repro.serve.server import Request, Server
    from repro.configs import registry
    cfg = registry.reduced("tinyllama-1.1b")
    params = api.init_params(cfg, jax.random.key(0))
    srv = Server(cfg, params, slots=2, cache_len=64)
    rng = np.random.default_rng(0)
    for rid in range(5):
        srv.submit(Request(rid=rid, prompt=rng.integers(
            0, cfg.vocab, 4).astype(np.int32), max_new=6))
    done = srv.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out) == 6 for r in done)
