"""Serial golden model vs vectorized JAX simulator (paper §7.3 methodology).

The GPU paper validates its parallel simulator against the serial C++ one;
we assert bit-identical statistics AND identical cycle counts.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.config import SimConfig
from repro.core.ref_serial import SerialSim
from repro.core.sim import VectorSim, run
from repro.core.trace import app_trace, random_trace


def final_stats_equal(cfg: SimConfig, trace) -> None:
    ref = SerialSim(cfg, trace).run()
    got = run(cfg, trace)
    assert ref == got, {k: (ref[k], got.get(k)) for k in ref
                        if ref[k] != got.get(k)}


@pytest.mark.parametrize("app,seed,dist", [
    ("matmul", 1, False),
    ("equake", 7, False),
    ("mgrid", 2, True),
    ("random", 3, True),
])
def test_end_to_end_identical(app, seed, dist):
    cfg = SimConfig(rows=4, cols=4, addr_bits=14, migrate_threshold=2,
                    centralized_directory=not dist)
    tr = (random_trace(cfg, 30, seed) if app == "random"
          else app_trace(cfg, app, 30, seed))
    final_stats_equal(cfg, tr)


def test_nonsquare_mesh():
    cfg = SimConfig(rows=3, cols=5, addr_bits=14)
    final_stats_equal(cfg, app_trace(cfg, "apsi", 25, 11))


def test_flat_vs_home_directory_layout():
    cfg = SimConfig(rows=4, cols=4, addr_bits=14,
                    centralized_directory=False)
    tr = app_trace(cfg, "wupwise", 30, 5)
    a = run(cfg, tr)
    b = run(dataclasses.replace(cfg, dir_layout="home"), tr)
    assert a == b


@settings(max_examples=3, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(
    rows=st.integers(2, 4),
    cols=st.integers(2, 4),
    refs=st.integers(10, 25),
    seed=st.integers(0, 100),
    thr=st.integers(1, 4),
    dist=st.booleans(),
)
def test_property_equivalence(rows, cols, refs, seed, thr, dist):
    """Any small config: serial and vectorized agree exactly."""
    cfg = SimConfig(rows=rows, cols=cols, addr_bits=13,
                    migrate_threshold=thr, centralized_directory=not dist)
    tr = random_trace(cfg, refs, seed)
    final_stats_equal(cfg, tr)


def test_lockstep_state():
    """Cycle-by-cycle: the first 300 cycles match on every FSM/stat field."""
    cfg = SimConfig(rows=3, cols=3, addr_bits=13, migrate_threshold=2)
    tr = app_trace(cfg, "matmul", 20, 4)
    ss = SerialSim(cfg, tr)
    vs = VectorSim(cfg, tr)
    for cyc in range(300):
        ss.step()
        vs.step()
        s = vs.state
        assert np.array_equal(ss.st, np.asarray(s.st)), cyc
        assert np.array_equal(ss.tr_ptr, np.asarray(s.tr_ptr)), cyc
        assert np.array_equal(
            np.array([len(q) for q in ss.sendq]), np.asarray(s.q_size)), cyc
        if ss.finished():
            break
    assert ss.finished() == bool(np.asarray(vs.stats()["finished"]))
