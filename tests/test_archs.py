"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness; one decode step with a KV/state cache."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.shapes import SHAPES, applicable
from repro.models import api


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_reduced_forward_train_decode(arch):
    cfg = registry.reduced(arch)
    rng = np.random.default_rng(0)
    params = api.init_params(cfg, jax.random.key(0))

    batch = api.make_inputs(cfg, "train", 2, 32, rng)
    logits, _ = jax.jit(lambda p, b: api.forward_logits(cfg, p, b))(
        params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    loss = jax.jit(lambda p, b: api.loss_fn(cfg, p, b))(params, batch)
    assert bool(jnp.isfinite(loss))

    g = jax.jit(jax.grad(lambda p: api.loss_fn(cfg, p, batch)))(params)
    gn = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda x: jnp.sum(jnp.abs(x.astype(jnp.float32))), g))
    assert bool(jnp.isfinite(gn)) and float(gn) > 0

    cache = api.init_cache(cfg, 2, 64)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (2, 1)), jnp.int32)
    lg, cache = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))(
        params, cache, tok)
    assert lg.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))
    assert int(cache["idx"]) == 1


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m",
                                  "hymba-1.5b", "whisper-small"])
def test_decode_matches_forward(arch):
    """Greedy decode through the cache must track the cache-free forward."""
    cfg = registry.reduced(arch)
    rng = np.random.default_rng(1)
    params = api.init_params(cfg, jax.random.key(1))
    b, s = 2, 12
    batch = api.make_inputs(cfg, "prefill", b, s, rng)
    ref_logits, _ = jax.jit(
        lambda p, bb: api.forward_logits(cfg, p, bb))(params, batch)

    cache = api.init_cache(cfg, b, 64)
    extra = {k: v for k, v in batch.items() if k != "tokens"}
    if cfg.family == "vlm":
        cache["img"] = batch["img"]
    dec = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))
    if cfg.family == "audio":
        # seed the encoder output into the cache via one prefill call
        from repro.models.zoo import _encode_audio
        cache["enc"] = _encode_audio(cfg, params, batch["frames"])
    outs = []
    for t in range(s):
        lg, cache = dec(params, cache, batch["tokens"][:, t:t + 1])
        outs.append(np.asarray(lg, np.float32))
    got = np.stack(outs, axis=1)
    ref = np.asarray(ref_logits, np.float32)
    # identical math, different code path: argmax agreement on ~all steps
    agree = np.mean(np.argmax(got, -1) == np.argmax(ref, -1))
    assert agree >= 0.9, agree


def test_applicability_matrix():
    cells = runnable = 0
    for arch in registry.ARCH_IDS:
        fam = registry.get(arch).family
        for s in SHAPES:
            cells += 1
            ok, why = applicable(fam, s)
            if ok:
                runnable += 1
            else:
                assert s == "long_500k" and fam not in ("ssm", "hybrid")
    assert cells == 40
    assert runnable == 32


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (non-reduced) configs carry the exact assigned geometry."""
    spec = {
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, None, 151936),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, None, 163840),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "mamba2-130m": (24, 768, None, None, None, 50280),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }[arch]
    cfg = registry.get(arch)
    L, d, h, kv, ff, v = spec
    assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab == v
    if h is not None:
        assert cfg.n_heads == h and cfg.kv_heads == kv
    if ff is not None:
        assert cfg.d_ff == ff
    if arch == "qwen2-moe-a2.7b":
        assert (cfg.moe_experts, cfg.moe_top_k, cfg.moe_shared,
                cfg.moe_d_ff) == (60, 4, 4, 1408)
    if arch == "moonshot-v1-16b-a3b":
        assert (cfg.moe_experts, cfg.moe_top_k, cfg.moe_d_ff) == (64, 6, 1408)
    if arch == "mamba2-130m":
        assert cfg.ssm_state == 128
    if arch == "hymba-1.5b":
        assert cfg.ssm_state == 16
