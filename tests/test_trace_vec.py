"""Vectorized trace synthesis vs the seed per-node-loop generators.

Two levels of parity:

* :func:`repro.core.trace.from_model_schedule` is **bit-identical** to the
  original loop (the only random draws are the activation block indices,
  and numpy's bounded-integer sampling consumes the PCG64 stream the same
  way scalar-by-scalar and in blocks).
* :func:`repro.core.trace.app_trace` draws its streams in a different
  (blocked, per-slab) order than the loop reference
  :func:`repro.core.trace.app_trace_loop`, so arrays differ element-wise;
  equivalence is asserted at the distribution level — region mix, zipf
  concentration of the shared region, hot-set reuse — which is what the
  simulator's traffic actually depends on.
"""
import numpy as np

from repro.core.config import SimConfig
from repro.core.trace import (app_trace, app_trace_loop, from_model_schedule,
                              random_trace, stacked_traces, TRACE_APPS)


def _fms_loop_reference(cfg, layer_params_bytes, d_model, n_layers,
                        refs_per_core=200, seed=0):
    """Verbatim copy of the seed per-node-loop from_model_schedule."""
    g = np.random.default_rng(np.random.PCG64(seed))
    n = cfg.num_nodes
    addr_space = 1 << cfg.addr_bits
    blk = cfg.cache.l2_block
    w_region = addr_space // 2
    act_region = addr_space - w_region
    shard = max(blk * 8, min(layer_params_bytes // max(1, n // n_layers),
                             w_region // n))
    out = np.full((n, refs_per_core), -1, dtype=np.int64)
    act_blocks = max(1, (d_model * 2) // blk)
    for node in range(n):
        layer = node % n_layers
        wbase = (node * shard) % max(blk, w_region - shard)
        abase = w_region + (layer * act_blocks * blk) % max(
            blk, act_region - act_blocks * blk)
        i = 0
        while i < refs_per_core:
            for _ in range(min(6, refs_per_core - i)):
                out[node, i] = wbase + ((i * blk) % shard)
                i += 1
            if i < refs_per_core:
                out[node, i] = abase + int(g.integers(0, act_blocks)) * blk
                i += 1
    return (out % addr_space).astype(np.int32)


def test_from_model_schedule_bit_identical_to_loop():
    cfg = SimConfig(rows=8, cols=8, addr_bits=16)
    for refs in (13, 14, 20, 21, 200):
        vec = from_model_schedule(cfg, 1 << 20, 512, 4, refs, seed=3)
        ref = _fms_loop_reference(cfg, 1 << 20, 512, 4, refs, seed=3)
        assert np.array_equal(vec, ref), refs


def test_app_trace_shape_dtype_range_determinism():
    cfg = SimConfig(rows=8, cols=8, addr_bits=16)
    for app in TRACE_APPS:
        t = app_trace(cfg, app, 37, seed=9)
        assert t.shape == (64, 37) and t.dtype == np.int32
        assert t.min() >= 0 and t.max() < (1 << cfg.addr_bits)
        assert np.array_equal(t, app_trace(cfg, app, 37, seed=9))
    assert not np.array_equal(app_trace(cfg, "matmul", 37, 1),
                              app_trace(cfg, "matmul", 37, 2))


def test_app_trace_multi_slab_deterministic():
    """A mesh spanning several synthesis slabs (8192 nodes each) is still a
    pure function of (cfg, app, refs, seed) under the thread pool."""
    cfg = SimConfig(rows=96, cols=96)        # 9216 nodes = 2 slabs
    a = app_trace(cfg, "equake", 10, seed=4)
    b = app_trace(cfg, "equake", 10, seed=4)
    assert a.shape == (9216, 10)
    assert np.array_equal(a, b)


def test_app_trace_distribution_matches_loop_reference():
    """Region mix and shared-region zipf concentration of the vectorized
    generator match the seed loop generator (same model parameters, a
    different PCG64 draw order)."""
    cfg = SimConfig(rows=8, cols=8, addr_bits=16)
    shared_hi = (1 << cfg.addr_bits) // 4
    refs = 400
    for app, params in TRACE_APPS.items():
        vec = app_trace(cfg, app, refs, seed=5)
        ref = app_trace_loop(cfg, app, refs, seed=5)
        # fraction of references landing in the shared region
        fv = float((vec < shared_hi).mean())
        fl = float((ref < shared_hi).mean())
        assert abs(fv - fl) < 0.05, (app, fv, fl)
        # the shared region is zipf-concentrated the same way: the single
        # hottest L2 block takes the same share of shared traffic
        blk = cfg.cache.l2_block
        sv, sl = vec[vec < shared_hi], ref[ref < shared_hi]
        top_v = np.bincount(sv // blk).max() / len(sv)
        top_l = np.bincount(sl // blk).max() / len(sl)
        assert abs(top_v - top_l) < 0.08, (app, top_v, top_l)
        # private-region traffic reuses a small hot set plus a stride
        # cursor: per-node unique-address count far below refs
        pv = vec[0][vec[0] >= shared_hi]
        assert len(np.unique(pv)) < len(pv), app


def test_app_trace_edge_node_neighbour_uniformity():
    """A 3-neighbour border node picks each neighbour uniformly (a modulo
    of a fixed-range draw would bias the first one to 1/2)."""
    cfg = SimConfig(rows=8, cols=8, addr_bits=16)
    shared_hi = (1 << cfg.addr_bits) // 4
    priv = max(cfg.cache.l2_block * 4,
               ((1 << cfg.addr_bits) - shared_hi) // cfg.num_nodes)
    node = 1                                  # top edge: neighbours 9, 0, 2
    tr = app_trace(cfg, "mgrid", 20_000, seed=3)[node]
    owners = (tr[tr >= shared_hi] - shared_hi) // priv
    counts = np.bincount(owners[np.isin(owners, (0, 2, 9))],
                         minlength=10)[[0, 2, 9]]
    assert counts.min() > 0
    assert counts.max() / counts.min() < 1.25, counts


def test_stacked_traces_uses_vectorized_generator():
    cfg = SimConfig(rows=4, cols=4, addr_bits=14)
    trs = stacked_traces(cfg, [("matmul", 0, 10), ("random", 1, 30)])
    assert trs.shape == (2, cfg.num_nodes, 30)
    assert np.all(trs[0, :, 10:] == -1)
    assert np.array_equal(trs[0, :, :10], app_trace(cfg, "matmul", 10, 0))
    assert np.array_equal(trs[1], random_trace(cfg, 30, 1))
