"""MoE dispatch and SSD correctness against slow oracles."""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.moe import _dispatch_groups, moe_ffn, top_k_routing
from repro.models.ssm import ssd_chunked, ssd_decode


def moe_cfg(e=8, k=2, cap=8.0) -> ModelConfig:
    return ModelConfig(name="m", family="moe", n_layers=1, d_model=16,
                       n_heads=2, d_ff=0, vocab=32, moe_experts=e,
                       moe_top_k=k, moe_d_ff=8, capacity_factor=cap)


def dense_moe_oracle(cfg, p, x):
    """Loop-over-tokens reference (no capacity drops when cap is large)."""
    b, s, d = x.shape
    xt = np.asarray(x, np.float32).reshape(-1, d)
    logits = xt @ np.asarray(p["router"], np.float32)
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        top = np.argsort(-logits[t])[:cfg.moe_top_k]
        ws = np.exp(logits[t][top] - logits[t][top].max())
        ws = ws / ws.sum()
        for w_, e_ in zip(ws, top):
            g = xt[t] @ np.asarray(p["w1"][e_], np.float32)
            u = xt[t] @ np.asarray(p["w3"][e_], np.float32)
            z = (g / (1 + np.exp(-g))) * u
            out[t] += w_ * (z @ np.asarray(p["w2"][e_], np.float32))
    return out.reshape(b, s, d)


def make_moe_params(cfg, seed=0):
    rng = np.random.default_rng(seed)
    d, e, f = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    mk = lambda *s: jnp.asarray(rng.standard_normal(s) * 0.3, jnp.float32)
    return {"router": mk(d, e), "w1": mk(e, d, f), "w3": mk(e, d, f),
            "w2": mk(e, f, d)}


def test_moe_matches_dense_oracle():
    cfg = moe_cfg(cap=16.0)    # big capacity: no drops -> exact
    p = make_moe_params(cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    got = np.asarray(moe_ffn(cfg, p, x), np.float32)
    want = dense_moe_oracle(cfg, p, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_topk_routing_properties():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    w, ids = top_k_routing(logits, 3)
    assert w.shape == (32, 3) and ids.shape == (32, 3)
    np.testing.assert_allclose(np.asarray(w).sum(-1), 1.0, rtol=1e-5)
    for row in np.asarray(ids):
        assert len(set(row.tolist())) == 3


def test_dispatch_groups_divide():
    for t in (1, 2, 7, 32, 128, 1_048_576):
        g = _dispatch_groups(t)
        assert t % g == 0 and g <= 32


@settings(max_examples=5, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(seed=st.integers(0, 1000), s=st.sampled_from([64, 128, 256]),
       h=st.integers(1, 4))
def test_ssd_chunked_matches_recurrence(seed, s, h):
    rng = np.random.default_rng(seed)
    b, p_, n = 2, 8, 16
    x = jnp.asarray(rng.standard_normal((b, s, h, p_)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((b, s, h))) * 0.1 + 0.01,
                     jnp.float32)
    a_log = jnp.asarray(rng.standard_normal(h) * 0.5, jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, s, n)) * 0.3, jnp.float32)
    cc = jnp.asarray(rng.standard_normal((b, s, n)) * 0.3, jnp.float32)

    a = -np.exp(np.asarray(a_log))
    hstate = np.zeros((b, h, p_, n))
    ys = []
    for t in range(s):
        decay = np.exp(np.asarray(dt[:, t]) * a[None, :])
        xdt = np.asarray(x[:, t]) * np.asarray(dt[:, t])[..., None]
        hstate = hstate * decay[:, :, None, None] + np.einsum(
            "bhp,bn->bhpn", xdt, np.asarray(bb[:, t]))
        ys.append(np.einsum("bhpn,bn->bhp", hstate, np.asarray(cc[:, t])))
    y_ref = np.stack(ys, 1)

    y, h_fin = ssd_chunked(x, dt, a_log, bb, cc)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h_fin), hstate, rtol=2e-3,
                               atol=2e-3)

    # decode continues exactly from the chunked state
    y2, h2 = ssd_decode(x[:, :1], dt[:, :1], a_log, bb[:, :1], cc[:, :1],
                        jnp.asarray(hstate))
    dec_ref_h = hstate * np.exp(np.asarray(dt[:, 0]) * a[None, :]
                                )[:, :, None, None] + np.einsum(
        "bhp,bn->bhpn",
        np.asarray(x[:, 0]) * np.asarray(dt[:, 0])[..., None],
        np.asarray(bb[:, 0]))
    np.testing.assert_allclose(np.asarray(h2), dec_ref_h, rtol=2e-3,
                               atol=2e-3)
