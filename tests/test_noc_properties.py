"""Property-based tests of the bufferless NoC invariants."""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

import jax.numpy as jnp

from repro.core.config import SimConfig
from repro.core.ref_serial import SerialSim
from repro.core.sim import run
from repro.core.trace import app_trace, random_trace
from repro.kernels.ref import arbitrate_ref


# ---------------------------------------------------------------------------
# arbitration properties (the paper's Fig. 3 router, §4.2 guarantees)
# ---------------------------------------------------------------------------

def random_arb_case(rng, n):
    age = rng.integers(0, 64, (n, 5)).astype(np.int32)
    vp = rng.random((n, 4)) < 0.85
    vp |= np.sum(vp, 1, keepdims=True) == 0
    valid = rng.random((n, 5)) < 0.6
    # bufferless invariant: candidates <= valid ports
    for i in range(n):
        nv = int(vp[i].sum())
        idx = np.where(valid[i])[0]
        for j in idx[nv:]:
            valid[i, j] = False
    we = (rng.random((n, 5)) < 0.2) & valid
    dc = rng.integers(-3, 4, (n, 5)).astype(np.int32)
    dr = rng.integers(-3, 4, (n, 5)).astype(np.int32)
    return age, valid, we, dc, dr, vp


@settings(max_examples=20, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(seed=st.integers(0, 10_000), n=st.integers(1, 64))
def test_arbitration_invariants(seed, n):
    rng = np.random.default_rng(seed)
    age, valid, we, dc, dr, vp = random_arb_case(rng, n)
    assigned, deflect = map(np.asarray, arbitrate_ref(
        *map(jnp.asarray, (age, valid, we, dc, dr, vp))))

    for i in range(n):
        got = assigned[i][valid[i]]
        # every valid candidate is assigned a port
        assert np.all(got >= 0)
        # ports are distinct
        assert len(set(got.tolist())) == len(got)
        # only physically existing ports are used
        assert all(vp[i, p] for p in got)
        # invalid candidates get nothing
        assert np.all(assigned[i][~valid[i]] == -1)
        # age priority: an older flit never gets a strictly worse port than
        # a younger flit *both wanting the same primary* — weaker form:
        # the oldest flit with a unique max age is never deflected unless
        # it wanted ejection or its primary port does not exist
        ages = np.where(valid[i], age[i], -1)
        if (ages == ages.max()).sum() == 1 and ages.max() >= 0:
            j = int(np.argmax(ages))
            if not we[i, j]:
                assert not deflect[i, j], (i, j)


# ---------------------------------------------------------------------------
# system-level conservation / liveness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("app,dist", [("matmul", False), ("random", True)])
def test_flit_conservation_and_liveness(app, dist):
    cfg = SimConfig(rows=4, cols=4, addr_bits=14, migrate_threshold=2,
                    centralized_directory=not dist)
    tr = (random_trace(cfg, 40, 3) if app == "random"
          else app_trace(cfg, app, 40, 3))
    stats = run(cfg, tr)
    assert stats["finished"] == 1, "simulation must terminate"
    # every injected flit is eventually delivered (bufferless: no drops)
    assert stats["injected"] == stats["flits_delivered"]
    assert stats["send_drop"] == 0
    # request/reply conservation: a redirected request is received at both
    # the stale owner and the forward target (paper §3.3 redirection)
    assert stats["req_rcvd"] == stats["req_made"] + stats["redirection"]
    assert stats["reply_sent"] == stats["reply_rcvd"]
    assert stats["wb_sent"] == stats["wb_rcvd"]
    assert stats["migrations"] == stats["migrations_done"]


def test_directory_consistency_at_quiescence():
    """At finish: each L2 tag appears once, and the directory points at it."""
    cfg = SimConfig(rows=4, cols=4, addr_bits=14, migrate_threshold=2)
    tr = random_trace(cfg, 40, 9)
    s = SerialSim(cfg, tr)
    s.run()
    assert s.finished()
    seen = {}
    n = cfg.num_nodes
    for node in range(n):
        tags = s.l2_tag[node][s.l2_tag[node] >= 0]
        for t in tags.tolist():
            assert t not in seen, f"tag {t} duplicated: {seen[t]} and {node}"
            seen[t] = node
    for t, node in seen.items():
        assert s.dir_loc[t] == node, (t, node, s.dir_loc[t])
    # no dangling directory entries
    for t in np.where(s.dir_loc >= 0)[0].tolist():
        assert t in seen, f"directory points at missing block {t}"


def test_migration_actually_triggers():
    """A node hammering remote blocks pulls them over (paper §3.3).

    Node 3 installs 8 blocks; node 0 first spins on a private block long
    enough for those installs to land, then hammers node 3's blocks with a
    1-way L1 that thrashes (all 8 L1 tags map to set 0), so every access
    re-requests remotely and the streak counter fires."""
    from repro.core.config import CacheConfig
    cfg = SimConfig(rows=2, cols=2, addr_bits=14, migrate_threshold=2,
                    l1_miss_cycles=1, l2_hit_cycles=1, mem_cycles=5,
                    cache=CacheConfig(l1_sets=2, l1_ways=1, l1_block=32,
                                      l2_sets=8, l2_ways=2, l2_block=64))
    n = cfg.num_nodes
    blocks = np.array([64 * i for i in range(1, 9)], np.int32)
    private = 64 * 100
    prefix = 200
    tr = np.full((n, prefix + 64), private, np.int32)  # idle nodes spin
    tr[3, :8] = blocks
    tr[3, 8:] = 64 * 101
    tr[0, prefix:] = np.tile(blocks, 8)
    stats = run(cfg, tr)
    assert stats["finished"] == 1
    assert stats["migrations"] >= 1, stats
    assert stats["migrations"] == stats["migrations_done"]


def test_migration_handler_unit():
    """Unit: repeated REQs from one node flip the streak counter and emit a
    B2 migration packet (vectorized phase-1a handler)."""
    import jax.numpy as jnp
    from repro.core import state as S
    from repro.core.cache import phase1a
    from repro.core.config import MSG_B2, MSG_REQ
    from repro.core.state import init_state, make_node_ctx
    from repro.core.config import CacheConfig

    cfg = SimConfig(rows=2, cols=2, addr_bits=14, migrate_threshold=2,
                    cache=CacheConfig(4, 2, 32, 4, 2, 64))
    tr = np.zeros((4, 4), np.int32)
    st = init_state(cfg, tr)
    ctx = make_node_ctx(cfg)
    # node 1 holds tag 7 in its L2
    st = st._replace(l2_tag=st.l2_tag.at[1, 7 % 4, 0].set(7))
    mig = 0
    for _ in range(2):   # two REQs from node 2 (threshold=2)
        pc = jnp.zeros((4, cfg.pc_depth, S.NUM_P), jnp.int32)
        pc = pc.at[1, 0].set(jnp.asarray([1, MSG_REQ, 2, 2, 7], jnp.int32))
        st = st._replace(pc=pc)
        st = phase1a(st, cfg, ctx)
    stats = {k: int(v) for k, v in zip(
        __import__("repro.core.ref_serial", fromlist=["STAT_NAMES"]).STAT_NAMES,
        np.asarray(st.stats))}
    assert stats["migrations"] == 1, stats
    assert int(st.l2_mig[1, 7 % 4, 0]) == 1
    # the B2 descriptor is in node 1's send queue
    q = np.asarray(st.q_desc[1])
    typs = q[:int(st.q_size[1]), 0].tolist()
    assert MSG_B2 in typs, typs


def test_centralized_directory_is_a_hotspot():
    """The paper's observation: the centralized directory serializes."""
    import dataclasses
    cfg = SimConfig(rows=6, cols=6, addr_bits=16)
    tr = random_trace(cfg, 20, 2)
    central = run(cfg, tr)
    dist = run(dataclasses.replace(cfg, centralized_directory=False), tr)
    assert central["finished"] == 1 and dist["finished"] == 1
    assert central["cycles"] > dist["cycles"], (central["cycles"],
                                                dist["cycles"])
