"""Driver-level progress monitors: livelock + directory saturation.

Both monitors live inside the single batched driver (`sim._run_jit`), so a
solo ``run`` exercises exactly the code path a batched ``run_sweep`` or a
planned bucket uses (solo = batch of one).  The assertions here use the
*real* pathologies catalogued in ROADMAP, not synthetic state:

* livelock — 16x16 / matmul / seed 0 / refs 20 with the seed loop-trace
  generator: ~255 nodes wedge in WAIT_DIR/WAIT_DATA with ~193 flits
  circulating forever (S14 backpressure / ejection-bar cycle);
* saturation — any centralized-directory run at 256 nodes drowns node 0
  (the paper's own observation, the reason it distributes the directory).

Both pathologies require ``pc_depth=1`` (the paper-faithful single S14
completion register) since the pending-completion queue's ejection
guarantee resolves them — the detectors now watch those runs *complete*
at the default depth (see ``tests/test_pc_queue.py``), so the tests here
pin the compatibility escape hatch to keep a real livelock to detect.
"""
from repro.core.config import SimConfig
from repro.core.sim import run
from repro.core.trace import app_trace, app_trace_loop

_DIAG_KEYS = ("circulating_flits", "wait_dir_nodes", "wait_data_nodes",
              "stalled_queues", "flits_to_node0")


def test_livelock_detector_aborts_roadmap_freeze():
    cfg = SimConfig(rows=16, cols=16, centralized_directory=False,
                    livelock_window=256, max_cycles=30_000, pc_depth=1)
    tr = app_trace_loop(cfg, "matmul", 20, 0)    # the exact ROADMAP combo
    st = run(cfg, tr, chunk=16)
    assert st["aborted"] == "livelock"
    assert st["finished"] == 0
    # aborted long before the cycle budget instead of burning it
    assert st["cycles"] < 15_000
    # the diagnostic surfaces the wedge: circulating flits + wait states
    assert st["circulating_flits"] > 50
    assert st["wait_dir_nodes"] + st["wait_data_nodes"] > 128
    for k in _DIAG_KEYS:
        assert k in st


def test_saturation_detector_aborts_centralized_hotspot():
    cfg = SimConfig(rows=16, cols=16, centralized_directory=True,
                    livelock_window=0,           # isolate the sat monitor
                    sat_window=1024, max_cycles=30_000, pc_depth=1)
    tr = app_trace(cfg, "matmul", 20, 1)
    st = run(cfg, tr, chunk=16)
    assert st["aborted"] == "dir_saturation"
    assert st["finished"] == 0
    assert st["cycles"] < 15_000
    assert st["cycles"] % 1024 == 0              # fires at a window edge
    # node-0 hotspot diagnostic
    assert st["wait_dir_nodes"] + st["wait_data_nodes"] >= 128
    assert st["flits_to_node0"] > 0


def test_healthy_run_reports_classic_keys_only():
    """Monitors never touch a healthy run: same key set, finished, and no
    abort — the bit-exactness guarantee the sweep/plan tests rely on."""
    from repro.core.ref_serial import STAT_NAMES
    cfg = SimConfig(rows=4, cols=4, addr_bits=14,
                    centralized_directory=False)
    st = run(cfg, app_trace(cfg, "equake", 25, seed=1), chunk=8)
    assert st["finished"] == 1
    assert set(st) == set(STAT_NAMES) | {"cycles", "finished"}


def test_monitors_match_serial_golden_model():
    """The golden-model equivalence contract covers the monitors: with an
    aggressively small window (freezes during ordinary memory stalls),
    SerialSim and the vectorized driver must produce the SAME dict —
    abort or no abort, same cycle, same diagnostics."""
    from repro.core.ref_serial import SerialSim
    cfg = SimConfig(rows=4, cols=4, addr_bits=14, mem_cycles=200,
                    migrate_threshold=2, centralized_directory=False,
                    livelock_window=16)
    tr = app_trace(cfg, "matmul", 12, seed=2)
    ref = SerialSim(cfg, tr).run()
    got = run(cfg, tr)
    assert ref == got, {k: (ref.get(k), got.get(k))
                        for k in set(ref) | set(got)
                        if ref.get(k) != got.get(k)}
    # the aggressive window must actually have fired for this to be a
    # meaningful parity check (a 200-cycle memory stall freezes stats)
    assert ref.get("aborted") == "livelock"


def test_livelock_window_zero_disables():
    cfg = SimConfig(rows=16, cols=16, centralized_directory=False,
                    livelock_window=0, sat_window=0, max_cycles=4_000,
                    pc_depth=1)
    tr = app_trace_loop(cfg, "matmul", 20, 0)
    st = run(cfg, tr, chunk=16)
    assert "aborted" not in st
    assert st["cycles"] == 4_000 and st["finished"] == 0
