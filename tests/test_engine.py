"""Execution-plan layer (repro.core.engine).

The planner's contract: a heterogeneous scenario list — mixed mesh shapes,
apps, seeds, policy knobs — compiles into exactly one device program per
structural bucket, and the per-scenario statistics are *bit-identical* to
sequential solo :func:`repro.core.sim.run` calls in the original order.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core import engine
from repro.core.config import SimConfig
from repro.core.sim import run, _run_jit
from repro.core.trace import app_trace, random_trace

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def solo_reference(sc: engine.Scenario):
    tr = (random_trace(sc.cfg, sc.refs_per_core, sc.seed)
          if sc.app == "random"
          else app_trace(sc.cfg, sc.app, sc.refs_per_core, sc.seed))
    return run(sc.cfg, tr, chunk=4)


def test_mixed_shape_plan_bit_exact_one_compile_per_bucket():
    """Interleaved 4x4/6x6 scenarios with knob variety: two buckets, two
    compiled programs, results bit-identical to solo runs, order kept."""
    # addr_bits=15 + refs 23/24 make the state shapes unique to this test,
    # so the jit-cache delta below counts exactly this plan's compiles.
    base = SimConfig(addr_bits=15, centralized_directory=False)
    scs = [
        engine.make_scenario(base, 4, 4, "matmul", 0, 23),
        engine.make_scenario(base, 6, 6, "equake", 1, 24),
        engine.make_scenario(base, 4, 4, "mgrid", 2, 23,
                             migration_enabled=False),
        engine.make_scenario(base, 6, 6, "random", 3, 24,
                             migrate_threshold=1),
        engine.make_scenario(base, 4, 4, "matmul", 5, 23,
                             centralized_directory=True),
    ]
    plan = engine.compile_plan(scs, ndev=1)
    desc = plan.describe()
    assert desc["n_buckets"] == 2, desc
    assert [b["batch"] for b in desc["buckets"]] == [3, 2]

    before = _run_jit._cache_size()
    got = engine.execute_plan(plan, chunk=4)
    assert _run_jit._cache_size() - before == 2, \
        "expected exactly one compile per shape bucket"

    assert got == [solo_reference(sc) for sc in scs]


def test_knobs_do_not_split_buckets_but_shapes_do():
    base = SimConfig(addr_bits=14, centralized_directory=False)
    scs = [
        engine.make_scenario(base, 4, 4, "matmul", 0, 10),
        engine.make_scenario(base, 4, 4, "matmul", 0, 10,
                             migration_enabled=False, migrate_threshold=2),
        engine.make_scenario(base, 4, 4, "matmul", 0, 10,
                             centralized_directory=True),
        # structural changes DO split:
        engine.make_scenario(base, 4, 8, "matmul", 0, 10),
        engine.make_scenario(base, 4, 4, "matmul", 0, 10, addr_bits=13),
        engine.make_scenario(base, 4, 4, "matmul", 0, 10, mem_cycles=40),
    ]
    plan = engine.compile_plan(scs, ndev=1)
    assert len(plan.buckets) == 4
    assert plan.buckets[0].batch == 3


def test_choose_tiling():
    assert engine.choose_tiling(16, 16, 8) in ((2, 4), (4, 2))
    assert engine.choose_tiling(16, 16, 1) == (1, 1)
    assert engine.choose_tiling(16, 16, 3) == (1, 2)   # 3 doesn't divide; 2 does
    assert engine.choose_tiling(6, 6, 4) == (2, 2)
    assert engine.choose_tiling(5, 7, 8) in ((1, 7), (5, 1))
    rt, ct = engine.choose_tiling(256, 256, 8)
    assert rt * ct == 8 and 256 % rt == 0 and 256 % ct == 0


def test_cost_model_backend_choice():
    base = SimConfig(centralized_directory=False)
    big = dataclasses.replace(base, rows=256, cols=256)
    small = dataclasses.replace(base, rows=16, cols=16)
    # huge solo scenario on several devices -> spatial sharding wins
    assert engine.choose_backend(big, batch=1, ndev=4)[0] == "sharded"
    # batched work -> scenario-parallel sweep (sharded has no batch axis)
    assert engine.choose_backend(big, batch=8, ndev=4)[0] == "sweep"
    # small mesh: fixed collective cost keeps it off shard_map
    assert engine.choose_backend(small, batch=1, ndev=4)[0] == "sweep"
    # single device: sharding impossible
    assert engine.choose_backend(big, batch=1, ndev=1)[0] == "sweep"
    # cost model sanity: sharded cost falls with devices
    c2 = engine.backend_cost("sharded", 1, 65536, 2, (1, 2))
    c8 = engine.backend_cost("sharded", 1, 65536, 8, (2, 4))
    assert c8 < c2 < engine.backend_cost("sweep", 1, 65536, 1)


def test_forced_sharded_falls_back_on_one_device():
    """--sharded on 1 device (the old degeneracy) degrades to the dense
    backend with an explanatory note instead of asserting."""
    base = SimConfig(rows=4, cols=4, addr_bits=14,
                     centralized_directory=False)
    sc = engine.make_scenario(base, app="matmul", seed=0, refs_per_core=10)
    plan = engine.compile_plan([sc], ndev=1, force_backend="sharded")
    b = plan.buckets[0]
    assert b.backend == "sweep" and "fell back" in b.note
    # centralized directory is never eligible for sharding
    sc2 = engine.make_scenario(base, centralized_directory=True)
    plan2 = engine.compile_plan([sc2], ndev=4, force_backend="sharded")
    assert plan2.buckets[0].backend == "sweep"
    assert "centralized" in plan2.buckets[0].note


def test_sharded_plan_on_short_device_list_degrades():
    """A plan compiled for more devices than the process has (ndev is a
    caller-supplied compile parameter) must still execute — via the dense
    backend — and stay bit-exact."""
    base = SimConfig(rows=4, cols=4, addr_bits=14,
                     centralized_directory=False)
    sc = engine.make_scenario(base, app="matmul", seed=1, refs_per_core=10)
    plan = engine.compile_plan([sc], ndev=4, force_backend="sharded")
    assert plan.buckets[0].backend == "sharded"     # planned for 4 devices
    got = engine.execute_plan(plan, chunk=4)        # ...but we have 1
    assert got == [solo_reference(sc)]


def test_manifest_loading():
    base = SimConfig(addr_bits=14, centralized_directory=False)
    obj = {"base": {"addr_bits": 13, "mem_cycles": 40},
           "scenarios": [
               {"rows": 4, "cols": 4, "app": "matmul", "seed": 2,
                "refs_per_core": 11},
               {"rows": 8, "cols": 4, "app": "random",
                "migration_enabled": False},
           ]}
    scs = engine.load_manifest(obj, base=base)
    assert scs[0].cfg.addr_bits == 13 and scs[0].cfg.mem_cycles == 40
    assert scs[0].refs_per_core == 11 and scs[0].seed == 2
    assert scs[1].cfg.rows == 8 and not scs[1].cfg.migration_enabled
    # JSON string and bare-list forms
    assert engine.load_manifest(json.dumps(obj), base=base) == scs
    assert engine.load_manifest(obj["scenarios"], base=base)[1].app == "random"
    # compact CLI grammar
    c = engine.load_manifest("4x4:matmul:0:10; 8x8:equake:3", base=base)
    assert (c[0].cfg.rows, c[0].app, c[0].seed, c[0].refs_per_core) \
        == (4, "matmul", 0, 10)
    assert (c[1].cfg.rows, c[1].app, c[1].seed, c[1].refs_per_core) \
        == (8, "equake", 3, 200)
    with pytest.raises(ValueError):
        engine.load_manifest({"scenarios": [{"rows": 4, "bogus_key": 1}]})
    with pytest.raises(ValueError):
        engine.load_manifest("totally not a manifest")
    with pytest.raises(ValueError):
        engine.load_manifest({"scenarios": []})


def test_sharded_backend_via_planner():
    """The planner's sharded backend (8 host devices, auto tiling) matches
    the solo run bit-exactly (subprocess so the main pytest process keeps
    its single CPU device)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, json
        sys.path.insert(0, "src")
        from repro.core.config import SimConfig
        from repro.core import engine
        from repro.core.sim import run
        from repro.core.trace import app_trace

        base = SimConfig(rows=8, cols=8, addr_bits=16,
                         centralized_directory=False, migrate_threshold=2)
        sc = engine.make_scenario(base, app="mgrid", seed=2,
                                  refs_per_core=30)
        plan = engine.compile_plan([sc], force_backend="sharded")
        b = plan.buckets[0]
        got = engine.execute_plan(plan)[0]
        ref = run(sc.cfg, app_trace(sc.cfg, "mgrid", 30, 2))
        print("RESULT " + json.dumps({
            "backend": b.backend, "tiles": list(b.tiles),
            "match": got == ref}))
    """)
    out = subprocess.run([sys.executable, "-c", code], cwd=REPO_ROOT,
                         capture_output=True, text=True, timeout=900)
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            res = json.loads(line[len("RESULT "):])
            assert res["backend"] == "sharded", res
            assert res["tiles"][0] * res["tiles"][1] == 8, res
            assert res["match"], res
            return
    raise AssertionError(
        f"no result\nstdout={out.stdout}\nstderr={out.stderr[-2000:]}")


def test_composed_backend_mixed_shape_plan_bit_exact():
    """Acceptance check: a mixed-shape plan forced through the composed
    backend (8 host devices, scenario x row x col grids) produces
    per-scenario stats bit-identical to sequential solo runs, in order."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import sys, json, dataclasses
        sys.path.insert(0, "src")
        from repro.core.config import SimConfig
        from repro.core import engine
        from repro.core.sim import run
        from repro.core.trace import app_trace

        base = SimConfig(addr_bits=16, centralized_directory=False)
        scs = [
            engine.make_scenario(base, 8, 8, "matmul", 0, 20),
            engine.make_scenario(base, 4, 4, "equake", 1, 15),
            engine.make_scenario(base, 8, 8, "mgrid", 2, 20,
                                 migration_enabled=False),
            engine.make_scenario(base, 4, 4, "matmul", 3, 15,
                                 migrate_threshold=1),
            engine.make_scenario(base, 8, 8, "equake", 4, 20),
        ]
        plan = engine.compile_plan(scs, force_backend="composed")
        got = engine.execute_plan(plan, chunk=4, sharded_chunk=64)
        ref = [run(dataclasses.replace(sc.cfg, dir_layout="home"),
                   app_trace(sc.cfg, sc.app, sc.refs_per_core, sc.seed),
                   chunk=4)
               for sc in scs]
        print("RESULT " + json.dumps({
            "backends": [b.backend for b in plan.buckets],
            "grids": [list(b.grid) for b in plan.buckets],
            "match": got == ref}))
    """)
    out = subprocess.run([sys.executable, "-c", code], cwd=REPO_ROOT,
                         capture_output=True, text=True, timeout=900)
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            res = json.loads(line[len("RESULT "):])
            assert res["backends"] == ["composed", "composed"], res
            for g in res["grids"]:
                assert g[0] >= 1 and g[1] * g[2] > 1, res
            assert res["match"], res
            return
    raise AssertionError(
        f"no result\nstdout={out.stdout}\nstderr={out.stderr[-2000:]}")


def test_plan_cli_smoke():
    """`--plan` end to end: compact manifest, two mesh shapes, JSON out."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.simulate",
         "--plan", "4x4:matmul:0:10;6x6:equake:1:8",
         "--max-cycles", "50000", "--chunk", "4"],
        cwd=REPO_ROOT, capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout)
    assert payload["plan"]["n_buckets"] == 2
    assert payload["n_scenarios"] == 2
    assert all(s["finished"] for s in payload["scenarios"])


def test_sharded_flag_deprecation_warning():
    """`--sharded` still works but is a deprecated alias for
    `--backend sharded`: it must emit a DeprecationWarning (and a stderr
    note for shell users) while producing the same run."""
    out = subprocess.run(
        [sys.executable, "-W", "error::DeprecationWarning",
         "-m", "repro.launch.simulate",
         "--rows", "4", "--cols", "4", "--refs", "10", "--sharded"],
        cwd=REPO_ROOT, capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"})
    assert out.returncode != 0          # -W error promotes it to a crash
    assert "--sharded is deprecated" in out.stderr, out.stderr[-2000:]

    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.simulate",
         "--rows", "4", "--cols", "4", "--refs", "10", "--sharded"],
        cwd=REPO_ROOT, capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"})
    assert out.returncode == 0, out.stderr[-2000:]
    assert "use --backend sharded" in out.stderr
    assert json.loads(out.stdout)["finished"]
