"""Shared test setup: put ``src/`` on ``sys.path`` and install a tiny
deterministic ``hypothesis`` fallback when the real package is missing.

Four test modules import ``hypothesis`` at module scope; in offline
environments without the package that used to abort *collection* of the
whole suite.  The shim keeps the property tests runnable everywhere: each
``@given`` test is executed ``max_examples`` times with values drawn from
a ``random.Random`` seeded by the test's qualified name, so runs are
reproducible (no shrinking, no database — it is a fallback, not a
replacement; the real package wins whenever it is importable).
"""
import functools
import inspect
import os
import random
import sys
import types

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _sampled_from(elements):
        elems = list(elements)
        return _Strategy(lambda rng: elems[rng.randrange(len(elems))])

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    class _HealthCheckMeta(type):
        def __iter__(cls):  # list(HealthCheck) -> [] (nothing to suppress)
            return iter(())

    class _HealthCheck(metaclass=_HealthCheckMeta):
        pass

    def _settings(**kwargs):
        def deco(fn):
            fn._shim_max_examples = kwargs.get("max_examples", 10)
            return fn
        return deco

    def _given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples", 10)
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            # hide the drawn parameters from pytest's fixture resolution
            # (functools.wraps exposes the original signature otherwise)
            del wrapper.__wrapped__
            params = [p for name, p in
                      inspect.signature(fn).parameters.items()
                      if name not in strategies]
            wrapper.__signature__ = inspect.Signature(params)
            return wrapper
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.floats = _floats

    _hyp = types.ModuleType("hypothesis")
    _hyp.HealthCheck = _HealthCheck
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_shim__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
