"""Narrow-dtype state layout (``SimConfig.state_dtype_policy``).

The contract under test: the ``packed`` layout is a pure *storage*
change — every backend computes in int32 behind cast-on-load /
cast-on-store boundaries, so results are bit-identical to the ``wide``
(all-int32) layout; the dtype map adapts to config bounds (and widens
back to int32 when a bound outgrows int16); invalid combinations fail
fast at validation instead of silently wrapping; and the base-2^30
hi/lo stats accumulator reconstructs exact totals past 2^31.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.config import SimConfig

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _packed(cfg):
    return dataclasses.replace(cfg, state_dtype_policy="packed")


# ---------------------------------------------------------------------------
# 1. bit parity: packed == wide, solo (in process)
# ---------------------------------------------------------------------------

def test_packed_solo_bit_identical():
    """Solo dense runs under packed vs wide agree on every counter, for
    a workload that exercises migration, directory search and
    deflections."""
    from repro.core.sim import run
    from repro.core.workloads import resolve_trace

    cfg = SimConfig(rows=4, cols=4, addr_bits=14,
                    centralized_directory=False, dir_layout="home")
    tr = resolve_trace(cfg, "matmul", 20, 0)
    wide = run(cfg, tr, chunk=4)
    packed = run(_packed(cfg), tr, chunk=4)
    assert wide == packed, {
        k: (wide.get(k), packed.get(k))
        for k in wide if wide.get(k) != packed.get(k)}


def test_packed_state_dtypes_narrow():
    """The packed state really allocates narrow leaves (the layout is
    not a no-op) and widen/narrow round-trips exactly."""
    import jax.numpy as jnp
    from repro.core.state import (init_state, leaf_dtypes, narrow_state,
                                  widen_state)

    cfg = _packed(SimConfig(rows=4, cols=4, addr_bits=14,
                            centralized_directory=False, dir_layout="home"))
    tr = np.zeros((cfg.num_nodes, 10), np.int32)
    s = init_state(cfg, tr)
    assert s.st.dtype == jnp.int8           # FSM states: 7 values
    assert s.l2_streak.dtype == jnp.int16   # fixed saturating streak
    assert s.l1_tag.dtype == jnp.int16      # addr_max >> l1_shift < 2^15
    assert s.stats.dtype == jnp.int32       # pinned: accumulator low word
    assert s.stats_hi.dtype == jnp.int32
    assert s.knob_mig.dtype == jnp.int32    # pinned: traced knob vectors

    dt = leaf_dtypes(cfg, 10)
    w = widen_state(s)
    assert all(getattr(w, f).dtype == jnp.int32
               for f in s._fields if f != "trace")
    back = narrow_state(w, dt)
    for f in s._fields:
        a, b = getattr(s, f), getattr(back, f)
        assert a.dtype == b.dtype and bool((a == b).all()), f


# ---------------------------------------------------------------------------
# 2. dtype map adapts to config bounds
# ---------------------------------------------------------------------------

def test_dtype_map_widens_with_bounds():
    """Growing a config bound past int16 widens exactly the affected
    leaves back to int32 — narrowing is bounds-driven, not hardcoded."""
    from repro.core.state import leaf_dtypes

    small = _packed(SimConfig(rows=4, cols=4, addr_bits=14,
                              max_cycles=8192,
                              centralized_directory=False,
                              dir_layout="home"))
    dt = leaf_dtypes(small, 10)
    assert dt["l2_tag"] == np.dtype(np.int16)
    assert dt["l1_owner"] == np.dtype(np.int8)   # node ids < 128

    # address space past 2^15 block tags -> tag arrays widen
    big_addr = dataclasses.replace(small, addr_bits=26)
    dt2 = leaf_dtypes(big_addr, 10)
    assert dt2["l2_tag"] == np.dtype(np.int32)
    assert dt2["l1_owner"] == np.dtype(np.int8)  # node ids unchanged

    # the paper-scale mesh: 43,264 node ids exceed int16 -> id fields
    # widen, FSM bytes stay narrow
    paper = dataclasses.replace(small, rows=208, cols=208)
    dt3 = leaf_dtypes(paper, 10)
    assert dt3["l1_owner"] == np.dtype(np.int32)
    assert dt3["dir_loc"] == np.dtype(np.int32)
    assert dt3["st"] == np.dtype(np.int8)

    # a longer cycle budget pushes the LRU clock past int16
    long_run = dataclasses.replace(small, max_cycles=60_000)
    assert leaf_dtypes(long_run, 10)["lru_clock"] == np.dtype(np.int32)
    assert leaf_dtypes(small, 10)["lru_clock"] == np.dtype(np.int16)

    # wide policy: everything int32 regardless of bounds
    wide = dataclasses.replace(small, state_dtype_policy="wide")
    assert set(leaf_dtypes(wide, 10).values()) == {np.dtype(np.int32)}


def test_state_bytes_ratio_and_live_match():
    """The analytic estimator matches real allocations leaf for leaf,
    and the packed layout is at most half the wide footprint at the
    representative config (the ISSUE's acceptance bar)."""
    import jax
    from repro.core.state import init_state, state_bytes

    cfg = SimConfig(rows=16, cols=16, addr_bits=14, max_cycles=8192,
                    centralized_directory=False, dir_layout="home")
    refs = 200
    wide = state_bytes(cfg, trace_len=refs)
    packed = state_bytes(cfg, trace_len=refs, policy="packed")
    assert packed <= 0.5 * wide, (packed, wide)

    for policy, expect in (("wide", wide), ("packed", packed)):
        c = dataclasses.replace(cfg, state_dtype_policy=policy)
        st = jax.eval_shape(
            lambda t: init_state(c, t),
            jax.ShapeDtypeStruct((c.num_nodes, refs), np.int32))
        got = sum(int(np.prod(l.shape)) * l.dtype.itemsize
                  for l in st._asdict().values())
        assert got == expect, (policy, got, expect)


# ---------------------------------------------------------------------------
# 3. validation fails fast
# ---------------------------------------------------------------------------

def test_validation_errors():
    from repro.core.sim import check_cycle_cap

    with pytest.raises(ValueError, match="state_dtype_policy"):
        SimConfig(rows=4, cols=4, state_dtype_policy="narrow").validate()
    # int16 l2_streak saturates at 32767: a threshold above 32766 could
    # never fire under packed storage
    with pytest.raises(ValueError, match="migrate_threshold"):
        _packed(SimConfig(rows=4, cols=4,
                          migrate_threshold=40_000)).validate()
    SimConfig(rows=4, cols=4, migrate_threshold=40_000).validate()  # wide ok

    # packed narrow counters are sized from cfg.max_cycles: a per-call
    # cap above it is rejected, wide accepts any cap
    packed = _packed(SimConfig(rows=4, cols=4, max_cycles=1000))
    with pytest.raises(ValueError, match="max_cycles"):
        check_cycle_cap(packed, 2000)
    check_cycle_cap(packed, 1000)
    check_cycle_cap(packed, None)
    check_cycle_cap(SimConfig(rows=4, cols=4, max_cycles=1000), 2000)


# ---------------------------------------------------------------------------
# 4. hi/lo stats accumulator: exact totals past int32
# ---------------------------------------------------------------------------

def test_fold_stats_and_totals_past_int32():
    import jax.numpy as jnp
    from repro.core.state import STATS_FOLD, fold_stats, stats_totals

    # totals well past 2^31, reconstructed exactly in int64
    hi = jnp.asarray([3, 0, 7], jnp.int32)
    lo = jnp.asarray([STATS_FOLD - 1, 5, STATS_FOLD + 17], jnp.int32)
    h2, l2 = fold_stats(hi, lo)
    tot = stats_totals(h2, l2)
    assert tot.dtype == np.int64
    assert tot.tolist() == [3 * STATS_FOLD + STATS_FOLD - 1, 5,
                            8 * STATS_FOLD + 17]
    # canonical invariant: lo in [0, 2^30)
    assert bool((l2 >= 0).all()) and bool((l2 < STATS_FOLD).all())
    # a negative transient (monitor bookkeeping) folds toward -inf, so
    # reconstruction stays exact rather than off by one
    h3, l3 = fold_stats(jnp.asarray([2], jnp.int32),
                        jnp.asarray([-3], jnp.int32))
    assert stats_totals(h3, l3).tolist() == [2 * STATS_FOLD - 3]


def test_stats_accumulate_past_int32_in_graph():
    """Seed the low word near the 2^30 fold boundary and step the real
    compiled driver: reported totals carry into the high word instead of
    wrapping (the int32-overflow regression this layout exists for)."""
    import jax.numpy as jnp
    from repro.core.sim import _run_jit, stats_list
    from repro.core.state import STATS_FOLD, init_state, stats_totals
    from repro.core.workloads import resolve_trace

    cfg = SimConfig(rows=4, cols=4, addr_bits=14,
                    centralized_directory=False, dir_layout="home")
    tr = resolve_trace(cfg, "matmul", 10, 0)

    seed_lo = STATS_FOLD - 7     # 7 increments from the fold boundary
    seed_hi = 3                  # pre-seeded total ~ 3.0 * 2^30 > 2^31
    # two independent states: _run_jit donates (consumes) its input, so
    # the seeded copy cannot share buffers with the plain one
    base = init_state(cfg, tr[None])
    seeded = init_state(cfg, tr[None])
    seeded = seeded._replace(
        stats=jnp.full_like(seeded.stats, seed_lo),
        stats_hi=jnp.full_like(seeded.stats_hi, seed_hi))
    cap = jnp.asarray(200, jnp.int32)
    s0, aux0 = _run_jit(base, cfg, cap, 1)
    s1, aux1 = _run_jit(seeded, cfg, cap, 1)
    plain = stats_totals(s0.stats_hi, s0.stats)[0]
    shifted = stats_totals(s1.stats_hi, s1.stats)[0]
    offset = seed_hi * STATS_FOLD + seed_lo
    assert (shifted - offset == plain).all(), (shifted, plain)
    assert int(shifted.max()) > 2**31       # really crossed int32
    # and the host dicts carry the exact values through stats_list
    d = stats_list(s1, aux1)[0]
    assert max(d.values()) > 2**31


def test_aggregate_and_health_near_int32():
    """Host-side roll-ups stay exact with per-scenario counters near
    2^31: sums cross int32 without wrapping and ratios are float64."""
    from repro.core.sim import STAT_NAMES, aggregate_stats, network_health

    big = 2**31 - 10
    scenarios = [dict({k: big for k in STAT_NAMES},
                      cycles=123, finished=1) for _ in range(4)]
    agg = aggregate_stats(scenarios)
    assert agg["hops"] == 4 * big > 2**31
    assert agg["cycles"] == 123 and agg["finished"] == 1
    health = network_health(agg)
    assert isinstance(health["deflection_rate"], float)
    assert health["deflection_rate"] == pytest.approx(1.0)
    assert health["hops_per_flit"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# 5. donation: the jitted driver updates the state in place
# ---------------------------------------------------------------------------

def test_run_jit_donates_state():
    """``_run_jit`` declares the state donated (aliased outputs in the
    lowered module) and really consumes the input buffers."""
    import jax.numpy as jnp
    from repro.core.sim import _run_jit
    from repro.core.state import init_state
    from repro.core.workloads import resolve_trace

    cfg = SimConfig(rows=4, cols=4, addr_bits=14,
                    centralized_directory=False, dir_layout="home")
    tr = resolve_trace(cfg, "matmul", 8, 0)
    s = init_state(cfg, tr[None])
    cap = jnp.asarray(50, jnp.int32)
    txt = _run_jit.lower(s, cfg, cap, 1).as_text()
    assert "tf.aliasing_output" in txt
    donated = s.st
    _run_jit(s, cfg, cap, 1)
    assert donated.is_deleted()


# ---------------------------------------------------------------------------
# 6. bit parity across all four backends (subprocess: 8 host devices)
# ---------------------------------------------------------------------------

def test_packed_bit_exact_across_backends():
    """The patterns-tiny zoo slice and a 16x16 wedge scenario, packed vs
    wide, through forced sweep / composed / sharded on an 8-device host
    mesh: every backend, both layouts, one set of answers."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import dataclasses, sys, json
        sys.path.insert(0, "src")
        from repro.core import engine
        from repro.core.config import SimConfig
        from repro.core.sim import run
        from repro.core.workloads import resolve_trace
        from repro.core.zoo import expand_zoo

        def repack(scs):
            return [dataclasses.replace(
                        sc, cfg=dataclasses.replace(
                            sc.cfg, state_dtype_policy="packed"))
                    for sc in scs]

        scs = expand_zoo("patterns-tiny:refs=8,seeds=0")
        wedge = expand_zoo("wedge:meshes=16x16,refs=6")
        res = {}

        solo = [run(sc.cfg,
                    resolve_trace(sc.cfg, sc.app, sc.refs_per_core, sc.seed),
                    chunk=4) for sc in scs]
        psolo = [run(sc.cfg,
                     resolve_trace(sc.cfg, sc.app, sc.refs_per_core, sc.seed),
                     chunk=4) for sc in repack(scs)]
        res["solo"] = psolo == solo
        res["sweep"] = engine.plan_and_run(
            repack(scs), chunk=4, force_backend="sweep") == solo
        res["composed"] = engine.plan_and_run(
            repack(scs), chunk=4, force_backend="composed") == solo
        res["sharded"] = [engine.plan_and_run([sc], chunk=4,
                                              force_backend="sharded")[0]
                          for sc in repack(scs)] == solo
        res["wedge"] = engine.plan_and_run(repack(wedge), chunk=4) \\
            == engine.plan_and_run(wedge, chunk=4)
        res["n"] = len(scs)
        print("RESULT " + json.dumps(res))
    """)
    out = subprocess.run([sys.executable, "-c", code], cwd=REPO_ROOT,
                         capture_output=True, text=True, timeout=900)
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            res = json.loads(line[len("RESULT "):])
            assert res["n"] >= 5, res
            for k in ("solo", "sweep", "composed", "sharded", "wedge"):
                assert res[k], (k, res)
            return
    raise AssertionError(f"no RESULT line\n{out.stdout}\n{out.stderr}")


# ---------------------------------------------------------------------------
# 7. memory-aware planner
# ---------------------------------------------------------------------------

def test_planner_memory_budget():
    from repro.core import engine

    assert engine.parse_mem_budget(None) is None
    assert engine.parse_mem_budget("4096") == 4096
    assert engine.parse_mem_budget("512M") == 512 * 2**20
    assert engine.parse_mem_budget("1.5g") == 3 * 2**29
    with pytest.raises(ValueError):
        engine.parse_mem_budget("lots")

    cfg = SimConfig(rows=16, cols=16, centralized_directory=False,
                    dir_layout="home")
    sc = engine.make_scenario(cfg, refs_per_core=50)
    need = engine.plan_state_bytes(cfg, 1, "sweep", (1, 1, 1), 1,
                                   trace_len=50)
    # a roomy budget plans normally and reports the footprint
    plan = engine.compile_plan([sc], ndev=1, mem_budget=4 * need)
    desc = plan.describe()
    assert desc["mem_budget"] == 4 * need
    b = desc["buckets"][0]
    assert b["policy"] == "wide"
    assert b["state_bytes_per_device"] == need
    # an impossible budget fails fast, naming the shortfall and the fix
    with pytest.raises(ValueError, match="state_dtype_policy"):
        engine.compile_plan([sc], ndev=1, mem_budget=need // 4)
    # packed state fits where wide does not
    packed_sc = engine.make_scenario(_packed(cfg), refs_per_core=50)
    packed_need = engine.plan_state_bytes(_packed(cfg), 1, "sweep",
                                          (1, 1, 1), 1, trace_len=50)
    assert packed_need < need
    plan2 = engine.compile_plan([packed_sc], ndev=1,
                                mem_budget=packed_need)
    assert plan2.describe()["buckets"][0]["policy"] == "packed"
