"""End-to-end behaviour tests for the paper's system."""
import numpy as np

from repro.core.config import SimConfig
from repro.core.sim import run
from repro.core.trace import TRACE_APPS, app_trace


def test_paper_table3_shape():
    """Per-application statistics exist and balance (paper Table 3)."""
    for app in TRACE_APPS:
        cfg = SimConfig(rows=4, cols=4, addr_bits=14)
        stats = run(cfg, app_trace(cfg, app, 25, seed=1))
        assert stats["finished"] == 1, app
        assert stats["req_rcvd"] == stats["req_made"] + stats["redirection"]
        assert stats["dir_search"] > 0
        assert stats["l1_hits"] + stats["l1_misses"] > 0


def test_scaling_is_sublinear_per_node():
    """The vectorized simulator's cost per node per cycle shrinks with N —
    the paper's Fig. 6 speedup story, reproduced on one host."""
    import time
    times = {}
    for rc in ((4, 4), (8, 8)):
        cfg = SimConfig(rows=rc[0], cols=rc[1], addr_bits=14)
        tr = app_trace(cfg, "matmul", 20, seed=1)
        run(cfg, tr)  # warm compile for this shape
        t0 = time.time()
        stats = run(cfg, tr)
        times[rc] = (time.time() - t0) / (stats["cycles"] * rc[0] * rc[1])
    assert times[(8, 8)] < times[(4, 4)], times
