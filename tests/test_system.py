"""End-to-end behaviour tests for the paper's system."""
import numpy as np

from repro.core.config import SimConfig
from repro.core.sim import run
from repro.core.trace import TRACE_APPS, app_trace


def test_paper_table3_shape():
    """Per-application statistics exist and balance (paper Table 3)."""
    for app in TRACE_APPS:
        cfg = SimConfig(rows=4, cols=4, addr_bits=14)
        stats = run(cfg, app_trace(cfg, app, 25, seed=1))
        assert stats["finished"] == 1, app
        assert stats["req_rcvd"] == stats["req_made"] + stats["redirection"]
        assert stats["dir_search"] > 0
        assert stats["l1_hits"] + stats["l1_misses"] > 0


def test_scaling_is_sublinear_per_node():
    """The vectorized simulator's cost per node per cycle shrinks with N —
    the paper's Fig. 6 speedup story, reproduced on one host.

    Wall-clock assertions flake on loaded CI runners, so measure where the
    effect is unambiguous: a 16x span of mesh sizes (4x4 vs 16x16), a
    chunked device loop (dispatch overhead otherwise dominates the small
    mesh), an unsaturated distributed directory, best-of-three timing, and
    a generous threshold (observed ratio is ~0.4; assert < 0.8)."""
    import time
    times = {}
    for rc in ((4, 4), (16, 16)):
        cfg = SimConfig(rows=rc[0], cols=rc[1], centralized_directory=False)
        tr = app_trace(cfg, "equake", 25, seed=1)
        run(cfg, tr, chunk=8)  # warm compile for this shape
        best = float("inf")
        for _ in range(3):
            t0 = time.time()
            stats = run(cfg, tr, chunk=8)
            best = min(best, time.time() - t0)
        assert stats["finished"] == 1, rc
        times[rc] = best / (stats["cycles"] * rc[0] * rc[1])
    assert times[(16, 16)] < times[(4, 4)] * 0.8, times
