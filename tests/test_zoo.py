"""Scenario-zoo registry (repro.core.zoo) + the tuning harness contract.

Fast checks (expansion is pure python) plus one subprocess smoke of
``benchmarks/zoo_tune.py --smoke`` — the same invocation the CI
``zoo-smoke`` job runs, asserting the recommendation JSON is
well-formed."""
import json
import os
import subprocess
import sys

import pytest

from repro.core import zoo
from repro.core.config import SimConfig
from repro.core.workloads import PATTERN_NAMES, valid_source

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_families_registered_and_sized():
    names = zoo.family_names()
    for required in ("patterns-tiny", "patterns-small", "patterns-rates",
                     "hotspot-stress", "apps-small", "wedge"):
        assert required in names, names
    f = zoo.get_family("patterns-small")
    assert f.size == 2 * len(PATTERN_NAMES) * 2 == len(f.expand())
    assert zoo.get_family("wedge").sources == ("loop:matmul",)
    assert len(zoo.zoo_summary().splitlines()) == len(names)


def test_every_family_source_parses():
    """Registration already guards this; the test pins it for families
    added later, and checks pattern families force the distributed
    directory (patterns need tag-home destinations)."""
    for name in zoo.family_names():
        f = zoo.get_family(name)
        for s in f.sources:
            assert valid_source(s), (name, s)
        if any(src.split(":")[0] in PATTERN_NAMES for src in f.sources):
            assert f.base.get("centralized_directory") is False, name


def test_expansion_is_plan_ready():
    scs = zoo.get_family("patterns-tiny").expand()
    assert len(scs) == 10
    for sc in scs:
        sc.validate()
        assert sc.cfg.rows == sc.cfg.cols == 4
        assert not sc.cfg.centralized_directory
    # cross-product order: mesh-major, then source, then seed
    assert [sc.seed for sc in scs[:2]] == [0, 1]
    assert scs[0].app == scs[1].app


def test_manifest_round_trips_through_load_manifest():
    from repro.core import engine
    fam = zoo.get_family("patterns-tiny")
    via_manifest = engine.load_manifest(fam.manifest())
    direct = fam.expand()
    assert [(s.cfg, s.app, s.seed, s.refs_per_core) for s in via_manifest] \
        == [(s.cfg, s.app, s.seed, s.refs_per_core) for s in direct]


def test_zoo_spec_overrides():
    scs = zoo.expand_zoo("patterns-small:refs=7,seeds=3+4,meshes=4x4")
    assert len(scs) == len(PATTERN_NAMES) * 2
    assert {sc.refs_per_core for sc in scs} == {7}
    assert {sc.seed for sc in scs} == {3, 4}
    assert {(sc.cfg.rows, sc.cfg.cols) for sc in scs} == {(4, 4)}
    scs = zoo.expand_zoo("wedge:sources=loop:matmul+random")
    assert [sc.app for sc in scs] == ["loop:matmul", "random"]
    with pytest.raises(ValueError, match="unknown zoo family"):
        zoo.expand_zoo("nope")
    with pytest.raises(ValueError, match="key=val"):
        zoo.expand_zoo("wedge:refs")
    with pytest.raises(ValueError, match="invalid source"):
        zoo.expand_zoo("wedge:sources=bogus")


def test_expand_respects_base_config():
    base = SimConfig(addr_bits=14, rob_slots=4)
    scs = zoo.expand_zoo("patterns-tiny", base=base)
    for sc in scs:
        assert sc.cfg.addr_bits == 14 and sc.cfg.rob_slots == 4
        assert not sc.cfg.centralized_directory   # family override wins


def test_zoo_tune_recommend_is_honest_about_unswept_defaults():
    """recommend() must not claim the defaults failed (or flip them)
    when they simply were not part of the swept grid."""
    sys.path.insert(0, os.path.join(REPO_ROOT, "benchmarks"))
    try:
        import zoo_tune
    finally:
        sys.path.pop(0)
    row = lambda t, a, norm, unfin=0: {
        "req_timeout": t, "eject_age_threshold": a, "finished": 5 - unfin,
        "unfinished": unfin, "aborted": 0, "unfinished_scenarios": [],
        "mean_norm_cycles": norm, "total_drops": 0}
    d = zoo_tune.DEFAULTS
    # defaults not in the grid: best reported, flip refused
    rec, flip, why = zoo_tune.recommend(
        [row(64, 2, 1.0), row(64, 4, 1.1)], 0.01)
    assert rec["req_timeout"] == 64 and not flip
    assert "not in the swept grid" in why
    # defaults swept and within margin: kept
    rec, flip, why = zoo_tune.recommend(
        [row(d["req_timeout"], d["eject_age_threshold"], 1.005),
         row(64, 2, 1.0)], 0.01)
    assert not flip and rec["req_timeout"] == d["req_timeout"]
    # defaults swept and beaten beyond margin: flipped
    rec, flip, why = zoo_tune.recommend(
        [row(d["req_timeout"], d["eject_age_threshold"], 1.1),
         row(64, 2, 1.0)], 0.01)
    assert flip and rec["req_timeout"] == 64
    # defaults swept but unsafe: flipped with the unfinished rationale
    rec, flip, why = zoo_tune.recommend(
        [row(d["req_timeout"], d["eject_age_threshold"], None, unfin=2),
         row(64, 2, 1.0)], 0.01)
    assert flip and "unfinished" in why


def test_zoo_tune_smoke_emits_wellformed_recommendation():
    """The CI zoo-smoke contract: --smoke self-checks and the stdout
    payload parses with table + recommendation + flip_defaults."""
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, "benchmarks/zoo_tune.py", "--smoke"],
        cwd=REPO_ROOT, capture_output=True, text=True, timeout=900,
        env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SMOKE OK" in out.stderr
    payload = json.loads(out.stdout[out.stdout.index("{"):])
    assert payload["table"] and payload["recommendation"] is not None
    assert set(payload["defaults"]) == {"eject_age_threshold",
                                        "req_timeout"}
    assert isinstance(payload["flip_defaults"], bool)
