"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ref import arbitrate_ref, attention_ref
from repro.kernels.router_phase import router_arbitrate_pallas
from tests.test_noc_properties import random_arb_case


@settings(max_examples=10, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(seed=st.integers(0, 10_000), n=st.integers(1, 400))
def test_router_kernel_bit_exact(seed, n):
    rng = np.random.default_rng(seed)
    case = random_arb_case(rng, n)
    a0, d0 = arbitrate_ref(*map(jnp.asarray, case))
    a1, d1 = router_arbitrate_pallas(*map(jnp.asarray, case), interpret=True)
    assert np.array_equal(np.asarray(a0), np.asarray(a1))
    assert np.array_equal(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("b,h,s,d,dtype,causal", [
    (1, 2, 256, 64, jnp.float32, True),
    (2, 1, 128, 128, jnp.bfloat16, True),
    (1, 4, 384, 64, jnp.float32, False),
    (2, 2, 512, 32, jnp.bfloat16, True),
])
def test_flash_attention_kernel(b, h, s, d, dtype, causal):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    o0 = attention_ref(q, k, v, causal=causal)
    o1 = flash_attention_pallas(q, k, v, causal=causal, interpret=True,
                                block_q=128, block_k=128)
    err = float(jnp.max(jnp.abs(o0.astype(jnp.float32)
                                - o1.astype(jnp.float32))))
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    assert err < tol, err


def test_blocked_xla_attention_matches_full():
    from repro.models.common import _blocked_attention, _mask_logits
    rng = np.random.default_rng(1)
    b, s, h, kv, d = 2, 256, 8, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def full(causal, window):
        rep = h // kv
        qg = (q / d ** 0.5).reshape(b, s, kv, rep, d)
        lg = jnp.einsum("bskrd,btkd->bkrst", qg, k).reshape(b, h, s, s)
        lg = _mask_logits(lg, pos, pos, causal, window)
        w = jax.nn.softmax(lg, -1).reshape(b, kv, rep, s, s)
        return jnp.einsum("bkrst,btkd->bskrd", w, v).reshape(b, s, h, d)

    for causal, window in [(True, 0), (True, 64), (False, 0)]:
        o1 = full(causal, window)
        o2 = _blocked_attention(q, k, v, pos, pos, causal, window,
                                qc=64, kc=32)
        assert float(jnp.max(jnp.abs(o1 - o2))) < 2e-5


def test_pallas_router_inside_simulator():
    """End-to-end: the sim with the Pallas router equals the ref path."""
    import dataclasses
    from repro.core.config import SimConfig
    from repro.core.sim import run
    from repro.core.trace import app_trace
    cfg = SimConfig(rows=3, cols=3, addr_bits=13, migrate_threshold=2)
    tr = app_trace(cfg, "matmul", 15, 1)
    a = run(cfg, tr)
    b = run(dataclasses.replace(cfg, use_pallas_router=True), tr)
    assert a == b


def test_banded_window_attention_matches():
    """Sliding-window banded iteration == full-band blocked attention."""
    from repro.models.common import _blocked_attention
    rng = np.random.default_rng(2)
    for (s, window, qc, kc) in [(512, 96, 64, 32), (1024, 200, 128, 64)]:
        b, h, kv, d = 2, 4, 2, 16
        q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        o1 = _blocked_attention(q, k, v, pos, pos, True, window,
                                qc=qc, kc=kc, banded=False)
        o2 = _blocked_attention(q, k, v, pos, pos, True, window,
                                qc=qc, kc=kc, banded=True)
        assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-5
