"""Composed scenario x row x col backend (repro.core.sharded.run_composed).

The contract under test: every degenerate device grid of the composed
backend is *exact* — a ``(1, 1, 1)`` grid on one device is a solo run, a
``(1, rt, ct)`` grid is the spatial backend, an indivisible scenario
axis pads with copies like ``run_sweep`` — all bit-identical to
sequential solo :func:`repro.core.sim.run` calls.  Plus the planner's
composed grid factoring and the calibration-file round trip.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core import engine
from repro.core.config import SimConfig

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# degeneracies that run on the lone in-process CPU device
# ---------------------------------------------------------------------------

def test_composed_single_device_degenerates_to_solo():
    """grid (1,1,1): the full composed machinery (3-axis mesh, batched
    shard_map, identity ppermutes) on ONE device must reproduce solo
    runs bit-identically — including a per-scenario policy knob."""
    from repro.core.sharded import run_composed
    from repro.core.sim import run
    from repro.core.sweep import ScenarioSpec, SweepSpec
    from repro.core.trace import app_trace

    cfg = SimConfig(rows=4, cols=4, addr_bits=14,
                    centralized_directory=False)
    spec = SweepSpec(cfg, (
        ScenarioSpec("matmul", seed=0, refs_per_core=10),
        ScenarioSpec("mgrid", seed=1, refs_per_core=12,
                     migration_enabled=False),
    ))
    got = run_composed(spec, (1, 1, 1), chunk=16)
    ref = []
    for sc in spec.scenarios:
        c = sc.resolve_cfg(dataclasses.replace(cfg, dir_layout="home"))
        ref.append(run(c, app_trace(c, sc.app, sc.refs_per_core, sc.seed)))
    assert got == ref, [
        {k: (a.get(k), b.get(k)) for k in b if a.get(k) != b.get(k)}
        for a, b in zip(got, ref)]


def test_composed_clamps_max_cycles():
    """An unfinished capped composed run stops at exactly max_cycles for
    every scenario (tail-chunk clamp), matching the dense backend."""
    from repro.core.sharded import run_composed
    from repro.core.sim import run
    from repro.core.sweep import ScenarioSpec, SweepSpec
    from repro.core.trace import app_trace

    cfg = SimConfig(rows=4, cols=4, addr_bits=14,
                    centralized_directory=False)
    spec = SweepSpec(cfg, (ScenarioSpec("mgrid", seed=1, refs_per_core=25),
                           ScenarioSpec("matmul", seed=0, refs_per_core=25)))
    got = run_composed(spec, (1, 1, 1), max_cycles=100, chunk=64)
    hc = dataclasses.replace(cfg, dir_layout="home")
    for sc, g in zip(spec.scenarios, got):
        c = sc.resolve_cfg(hc)
        ref = run(c, app_trace(c, sc.app, sc.refs_per_core, sc.seed),
                  max_cycles=100)
        assert g["cycles"] == 100 and g["finished"] == 0
        assert g == ref


def test_composed_rejects_centralized_and_short_device_list():
    from repro.core.sharded import run_composed
    from repro.core.sweep import ScenarioSpec, SweepSpec

    cfg = SimConfig(rows=4, cols=4, addr_bits=14,
                    centralized_directory=False)
    with pytest.raises(ValueError, match="centralized"):
        run_composed(SweepSpec(cfg, (
            ScenarioSpec("matmul", centralized_directory=True),)),
            (1, 1, 1))
    # ask for one more device than the host exposes, whatever that is
    # (this suite also runs under XLA_FLAGS-faked multi-device hosts)
    import jax
    bs_over = len(jax.devices()) // 4 + 1
    with pytest.raises(ValueError, match="device"):
        run_composed(SweepSpec(cfg, (ScenarioSpec("matmul"),)),
                     (bs_over, 2, 2))


def test_composed_batched_livelock_abort_with_healthy_batchmate():
    """Per-scenario host monitor: the ROADMAP livelock wedge (16x16 /
    matmul / seed 0 / refs 20, loop-trace) aborts with its diagnostic
    while the healthy scenario sharing the batch finishes bit-identically
    to its solo run.  The wedge needs the paper-faithful ``pc_depth=1``
    escape hatch — the default pending-completion queue resolves it
    (tests/test_pc_queue.py)."""
    import jax
    import numpy as np
    from repro.core.sharded import ShardedSim
    from repro.core.sim import run
    from repro.core.trace import app_trace, app_trace_loop

    cfg = SimConfig(rows=16, cols=16, centralized_directory=False,
                    dir_layout="home", max_cycles=30_000, pc_depth=1)
    wedge = app_trace_loop(cfg, "matmul", 20, 0)   # the exact ROADMAP combo
    healthy = app_trace(cfg, "equake", 10, 1)
    m = max(wedge.shape[1], healthy.shape[1])
    tr = np.full((2, cfg.num_nodes, m), -1, np.int32)   # -1 = exhaustion pad
    tr[0, :, :wedge.shape[1]] = wedge
    tr[1, :, :healthy.shape[1]] = healthy
    mesh = jax.make_mesh((1, 1, 1), ("scenario", "data", "model"))
    got = ShardedSim(cfg, tr, mesh, batch_axes=("scenario",)).run(chunk=128)

    assert got[0]["aborted"] == "livelock"
    assert got[0]["finished"] == 0
    assert got[0]["cycles"] < 30_000      # aborted, not budget-burned
    assert got[0]["circulating_flits"] > 50
    assert got[0]["wait_dir_nodes"] + got[0]["wait_data_nodes"] > 128
    assert got[1] == run(cfg, healthy)


# ---------------------------------------------------------------------------
# real scenario-axis + spatial sharding (subprocess: 8 host devices)
# ---------------------------------------------------------------------------

def test_composed_grid_batch_padding_and_spatial_degeneracy():
    """On a 2x2x2 device grid: an indivisible batch of 3 pads to 4 and
    stays bit-identical to solo runs; a batch of 1 on a (1,2,2) grid
    matches the spatial ShardedSim and the solo run."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import sys, json, dataclasses
        sys.path.insert(0, "src")
        import jax
        from repro.core.config import SimConfig
        from repro.core.sharded import ShardedSim, run_composed
        from repro.core.sim import run
        from repro.core.sweep import ScenarioSpec, SweepSpec
        from repro.core.trace import app_trace

        cfg = SimConfig(rows=8, cols=8, addr_bits=16,
                        centralized_directory=False, migrate_threshold=2)
        spec = SweepSpec(cfg, (
            ScenarioSpec("mgrid", seed=2, refs_per_core=30),
            ScenarioSpec("matmul", seed=0, refs_per_core=25,
                         migration_enabled=False),
            ScenarioSpec("equake", seed=1, refs_per_core=20,
                         migrate_threshold=1),
        ))
        got = run_composed(spec, (2, 2, 2), chunk=64)
        hc = dataclasses.replace(cfg, dir_layout="home")
        ref = []
        for sc in spec.scenarios:
            c = sc.resolve_cfg(hc)
            ref.append(run(c, app_trace(c, sc.app, sc.refs_per_core,
                                        sc.seed)))

        one = SweepSpec(cfg, (spec.scenarios[0],))
        got1 = run_composed(one, (1, 2, 2), chunk=64)[0]
        c0 = spec.scenarios[0].resolve_cfg(hc)
        tr0 = app_trace(c0, "mgrid", 30, 2)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        spatial = ShardedSim(c0, tr0, mesh).run(chunk=64)
        print("RESULT " + json.dumps({
            "batch3_match": got == ref,
            "batch1_match": got1 == spatial == ref[0]}))
    """)
    out = subprocess.run([sys.executable, "-c", code], cwd=REPO_ROOT,
                         capture_output=True, text=True, timeout=900)
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            res = json.loads(line[len("RESULT "):])
            assert res["batch3_match"], res
            assert res["batch1_match"], res
            return
    raise AssertionError(
        f"no result\nstdout={out.stdout}\nstderr={out.stderr[-2000:]}")


# ---------------------------------------------------------------------------
# planner: grid factoring, backend choice, fallbacks
# ---------------------------------------------------------------------------

def test_choose_grid_factoring():
    # 8 devices, batch 2, 256x256: scenario axis takes 2, space takes 4
    grid, cost = engine.choose_grid(2, 256, 256, 8)
    assert grid[0] == 2 and grid[1] * grid[2] == 4
    assert cost < engine.backend_cost("sweep", 2, 256 * 256, 8)
    # one device: no composed grid exists
    assert engine.choose_grid(4, 16, 16, 1) == ((1, 1, 1), float("inf"))
    # batch 1 degenerates to the pure spatial factoring
    g1, c1 = engine.choose_grid(1, 256, 256, 8)
    assert g1[0] == 1 and g1[1] * g1[2] == 8
    assert c1 == engine.backend_cost("sharded", 1, 256 * 256, 8, g1[1:])


def test_backend_choice_composed():
    base = SimConfig(centralized_directory=False)
    big = dataclasses.replace(base, rows=256, cols=256)
    small = dataclasses.replace(base, rows=16, cols=16)
    # numerous AND large with devices to spare on both axes -> composed
    b, grid, note = engine.choose_backend(big, batch=2, ndev=8)
    assert b == "composed" and grid[0] == 2 and grid[1] * grid[2] == 4, \
        (b, grid, note)
    # batch >= devices: sweep already keeps every device busy
    assert engine.choose_backend(big, batch=8, ndev=4)[0] == "sweep"
    # batch == 1 belongs to the spatial backend, not composed
    assert engine.choose_backend(big, batch=1, ndev=8)[0] == "sharded"
    # small meshes never pay the collective cost
    assert engine.choose_backend(small, batch=2, ndev=8)[0] == "sweep"
    # centralized directory bars both spatial backends
    cen = dataclasses.replace(big, centralized_directory=True)
    assert engine.choose_backend(cen, batch=2, ndev=8)[0] == "sweep"


def test_forced_composed_falls_back_on_one_device():
    base = SimConfig(rows=4, cols=4, addr_bits=14,
                     centralized_directory=False)
    scs = [engine.make_scenario(base, app="matmul", seed=s,
                                refs_per_core=10) for s in range(2)]
    plan = engine.compile_plan(scs, ndev=1, force_backend="composed")
    b = plan.buckets[0]
    assert b.backend == "sweep" and "fell back" in b.note
    # with devices it sticks, and describe() reports the grid
    plan2 = engine.compile_plan(scs, ndev=8, force_backend="composed")
    b2 = plan2.buckets[0]
    assert b2.backend == "composed" and b2.devices_needed <= 8
    assert plan2.describe()["buckets"][0]["grid"] == list(b2.grid)


def test_composed_plan_on_short_device_list_degrades():
    """A composed plan compiled for 8 devices must still execute on this
    1-device process — via the sweep backend — and stay bit-exact."""
    from repro.core.sim import run
    from repro.core.trace import app_trace

    base = SimConfig(rows=4, cols=4, addr_bits=14,
                     centralized_directory=False)
    scs = [engine.make_scenario(base, app="matmul", seed=s,
                                refs_per_core=10) for s in range(2)]
    plan = engine.compile_plan(scs, ndev=8, force_backend="composed")
    assert plan.buckets[0].backend == "composed"
    got = engine.execute_plan(plan, chunk=4)
    ref = [run(sc.cfg, app_trace(sc.cfg, sc.app, sc.refs_per_core, sc.seed),
               chunk=4) for sc in scs]
    assert got == ref


# ---------------------------------------------------------------------------
# cost-model constants: calibration-file round trip
# ---------------------------------------------------------------------------

def test_cost_constants_roundtrip(tmp_path):
    defaults = engine.cost_constants()
    try:
        c = engine.CostConstants(halo_overhead=2.5, shard_fixed=512.0,
                                 batch_fixed=96.0)
        path = str(tmp_path / "cost_model.json")
        engine.save_cost_constants(path, c, meta={"devices": 8,
                                                  "note": "test"})
        loaded = engine.load_cost_constants(path)
        assert loaded == c == engine.cost_constants()
        # meta survives on disk but never leaks into the constants
        with open(path) as f:
            obj = json.load(f)
        assert obj["meta"]["devices"] == 8
        # the planner actually uses the loaded values
        assert engine.backend_cost("sharded", 1, 4096, 4, (2, 2)) \
            == 4096 / 4 * 2.5 + 512.0
        assert engine.backend_cost("composed", 4, 4096, 4, (2, 1, 2)) \
            == 2 * 4096 / 2 * 2.5 + 512.0 + 96.0
    finally:
        engine.set_cost_constants(defaults)
    assert engine.cost_constants() == defaults
