"""Benchmark harness: every benchmark through the one entry contract.

    PYTHONPATH=src python benchmarks/run.py --smoke --out-dir results
    PYTHONPATH=src python benchmarks/run.py --only plan,sweep,trace
    PYTHONPATH=src python benchmarks/run.py --list

Each module under ``benchmarks/`` registers a ``BENCH``
(:class:`repro.bench.contract.Benchmark`) and is invoked uniformly —
same ``--smoke/--out/--json`` flags, same ``BenchReport`` output — in a
fresh subprocess (several benchmarks must set ``XLA_FLAGS`` device
exposure *before* jax loads, which only a clean interpreter guarantees).
``--out-dir`` collects one ``BENCH_<area>.json`` per area: the files
``scripts/bench_gate.py`` diffs against the committed repo-root
baselines.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

BENCH_DIR = Path(__file__).resolve().parent
REPO_ROOT = BENCH_DIR.parent

#: area -> benchmark file; the single source the harness, the gate and
#: CI share.  Order is execution order (cheap first).
AREA_FILES = {
    "trace": "trace_throughput.py",
    "sweep": "sweep_throughput.py",
    "plan": "plan_throughput.py",
    "fig6": "fig6_scaling.py",
    "table3": "table3_stats.py",
    "table4": "table4_memory.py",
    "roofline": "roofline.py",
    "paper_scale": "paper_scale.py",
}

#: areas with committed repo-root BENCH_<area>.json baselines —
#: ``scripts/bench_gate.py --smoke`` runs and diffs exactly these.
#: ``paper_scale`` also has a committed baseline but is gated by its own
#: dedicated CI job (a 43k-core mesh is minutes of work, not seconds):
#: ``bench_gate.py --smoke --areas paper_scale``.
GATED_AREAS = ("trace", "sweep", "plan", "table4")


def load_bench(area: str):
    """Import ``benchmarks/<file>`` for ``area`` and return its ``BENCH``
    registration (metadata only — running happens in a subprocess)."""
    import importlib.util
    path = BENCH_DIR / AREA_FILES[area]
    spec = importlib.util.spec_from_file_location(f"bench_{area}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    bench = mod.BENCH
    assert bench.area == area, (bench.area, area)
    return bench


def invoke(area: str, smoke: bool = False, out: str | None = None,
           extra: list[str] | None = None) -> int:
    """Run one benchmark uniformly in a subprocess; returns its exit code."""
    cmd = [sys.executable, str(BENCH_DIR / AREA_FILES[area])]
    if smoke:
        cmd.append("--smoke")
    if out:
        cmd += ["--out", out]
    cmd += extra or []
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run(cmd, cwd=REPO_ROOT, env=env).returncode


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Uniform benchmark harness over the BENCH registry.")
    ap.add_argument("--only", default=None,
                    help="comma list of areas to run (default: all); "
                         "'gated' = the baseline-gated set "
                         + ",".join(GATED_AREAS))
    ap.add_argument("--smoke", action="store_true",
                    help="run every benchmark at its smoke tier")
    ap.add_argument("--out-dir", default=None, metavar="DIR",
                    help="write one BENCH_<area>.json per area here "
                         "(pass '.' to refresh the committed baselines)")
    ap.add_argument("--list", action="store_true",
                    help="list registered benchmarks and exit")
    args = ap.parse_args(argv)

    if args.list:
        for area in AREA_FILES:
            b = load_bench(area)
            mark = "*" if area in GATED_AREAS else " "
            print(f" {mark} {area:<9s} {AREA_FILES[area]:<22s} {b.title}")
        print(" (* = gated against a committed BENCH_<area>.json baseline)")
        return 0

    if args.only == "gated":
        areas = list(GATED_AREAS)
    elif args.only:
        areas = [a.strip() for a in args.only.split(",")]
        unknown = [a for a in areas if a not in AREA_FILES]
        if unknown:
            ap.error(f"unknown areas {unknown}; known: {list(AREA_FILES)}")
    else:
        areas = list(AREA_FILES)

    if args.out_dir:
        Path(args.out_dir).mkdir(parents=True, exist_ok=True)

    failed = []
    for area in areas:
        out = str(Path(args.out_dir) / f"BENCH_{area}.json") \
            if args.out_dir else None
        print(f"\n== {area} ({AREA_FILES[area]}"
              f"{', smoke' if args.smoke else ''}) ==", flush=True)
        t0 = time.time()
        rc = invoke(area, smoke=args.smoke, out=out)
        print(f"-- {area}: exit {rc} in {time.time() - t0:.1f}s --",
              flush=True)
        if rc:
            failed.append(area)
    if failed:
        print(f"\nFAILED areas: {failed}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
