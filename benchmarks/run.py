"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark plus the full
tables.  Roofline rows come from the dry-run artifacts when present.
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def _timed(name, fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    dt = (time.time() - t0) * 1e6
    print(f"CSV,{name},{dt:.0f},ok")
    return out


def main() -> None:
    from benchmarks import fig6_scaling, roofline, table3_stats, table4_memory

    print("== Table 3: per-application statistics ==")
    _timed("table3_stats", table3_stats.main, 8, 8, 60,
           "results/table3.json")

    print("\n== Figure 6: serial vs vectorized scaling ==")
    _timed("fig6_scaling", fig6_scaling.main,
           ((4, 4), (8, 8), (16, 16)), 40, 300, "results/fig6.json")

    print("\n== Table 4: cache config vs max simulated cores ==")
    _timed("table4_memory", table4_memory.main, "results/table4.json")

    print("\n== Roofline (from dry-run artifacts) ==")
    if Path("results/dryrun").exists() and \
            any(Path("results/dryrun").glob("*.json")):
        _timed("roofline", roofline.main)
    else:
        print("(run `python -m repro.launch.dryrun --all` first)")


if __name__ == "__main__":
    main()
