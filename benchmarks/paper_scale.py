"""Paper-scale smoke: the 208x208 (43,264-core) mesh end to end.

    PYTHONPATH=src python benchmarks/paper_scale.py [--smoke] [--out f]

The source paper's headline is simulating a 43k-core bufferless mesh
within one GTX 690's memory.  This benchmark runs that exact mesh shape
through the dense driver under the ``packed`` state-dtype policy — the
layout that makes the footprint practical — for a small, fixed number of
cycles, and gates on *completion*: the run must reach the cycle cap
without aborting.  A capped run is deliberate: CI measures that the
paper-scale state allocates, compiles and steps on a CPU host in
minutes; full-length runs belong on real accelerators.

Gated metrics: the completion flag and the analytic bytes/node under
both dtype policies at this exact config (any state growth at paper
scale shows up here).  Wall-clock and throughput are reported ungated —
CI hosts vary.
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

import numpy as np                                              # noqa: E402

from repro.bench import BenchReport, Benchmark, bench_main      # noqa: E402
from repro.core import SimConfig                                # noqa: E402
from repro.core.state import state_bytes                        # noqa: E402


def add_args(ap) -> None:
    ap.add_argument("--rows", type=int, default=208,
                    help="mesh rows (paper scale: 208)")
    ap.add_argument("--cols", type=int, default=208,
                    help="mesh columns (paper scale: 208)")
    ap.add_argument("--max-cycles", type=int, default=64,
                    help="cycle cap for the completion smoke")
    ap.add_argument("--refs", type=int, default=8,
                    help="memory references per core")
    ap.add_argument("--policy", choices=("packed", "wide"),
                    default="packed",
                    help="state-dtype policy to run under")


def run_bench(args) -> BenchReport:
    """Contract entry: run the paper-scale mesh to its cycle cap."""
    from repro.core import sim
    from repro.core.workloads import random_trace

    cfg = SimConfig(rows=args.rows, cols=args.cols,
                    max_cycles=args.max_cycles,
                    centralized_directory=False, dir_layout="home",
                    state_dtype_policy=args.policy)
    n = cfg.num_nodes
    bw = state_bytes(cfg, trace_len=args.refs, policy="wide") // n
    bp = state_bytes(cfg, trace_len=args.refs, policy="packed") // n
    print(f"{args.rows}x{args.cols} = {n:,} cores, {args.refs} refs/core, "
          f"cap {args.max_cycles} cycles, policy={args.policy}")
    print(f"state bytes/node: wide {bw}  packed {bp} "
          f"(total {args.policy}: "
          f"{(bp if args.policy == 'packed' else bw) * n / 2**20:.0f} MiB)")

    tr = random_trace(cfg, refs_per_core=args.refs, seed=0)
    t0 = time.time()
    r = sim.run(cfg, tr, max_cycles=args.max_cycles, chunk=args.max_cycles)
    wall = time.time() - t0
    completed = int("aborted" not in r
                    and (r["cycles"] == args.max_cycles or r["finished"] == n))
    print(f"ran {r['cycles']} cycles in {wall:.1f}s "
          f"({'completed' if completed else 'ABORTED: ' + str(r.get('aborted'))}, "
          f"{r['flits_delivered']:,} flits delivered)")

    rep = BenchReport("paper_scale", raw={
        "rows": args.rows, "cols": args.cols, "nodes": n,
        "refs": args.refs, "policy": args.policy, "wall_s": round(wall, 2),
        "stats": {k: int(v) for k, v in r.items() if isinstance(v, int)}})
    tags = {"mesh": f"{args.rows}x{args.cols}", "policy": args.policy}
    rep.add("paper_scale.completed", completed, unit="flag",
            direction="higher", tags=tags)
    rep.add("paper_scale.state_bytes_per_node.wide", bw, unit="B/node",
            direction="lower", tags={"mesh": tags["mesh"]})
    rep.add("paper_scale.state_bytes_per_node.packed", bp, unit="B/node",
            direction="lower", tags={"mesh": tags["mesh"]})
    rep.add("paper_scale.wall_s", round(wall, 2), unit="s",
            direction="lower", gate=False, tags=tags)
    rep.add("paper_scale.node_cycles_per_sec",
            round(n * r["cycles"] / wall), unit="node*cyc/s",
            direction="higher", gate=False, tags=tags)
    return rep


BENCH = Benchmark(
    area="paper_scale",
    title="Paper-scale smoke: 208x208 (43k cores) completes under packed "
          "state",
    add_args=add_args,
    run=run_bench,
    smoke={"max_cycles": 32},
    gated=True,
)


def main(argv=None) -> BenchReport:
    return bench_main(BENCH, argv)


if __name__ == "__main__":
    main()
