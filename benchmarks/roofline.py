"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

    PYTHONPATH=src python benchmarks/roofline.py [--out f]

TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
``compiled.cost_analysis()`` on an SPMD-partitioned executable reports
PER-DEVICE FLOPs/bytes (verified empirically: an 8-way sharded matmul
reports 1/8 of global FLOPs), so each term is computed per chip directly:

    compute    = flops_per_device / 197e12            [s]
    memory     = bytes_per_device / 819e9             [s]
    collective = collective_bytes_per_device / 50e9   [s]

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE), divided by the device
count for the per-device useful-compute ratio.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, "src")

from repro.bench import BenchReport, Benchmark, bench_main      # noqa: E402

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def analyse(cell: dict) -> dict:
    corr = cell.get("corrected") or {}
    if "error" in corr:
        corr = {}
    devices = max(cell.get("devices", 1), 1)
    # compute term: analytic FLOPs (exact; scan bodies are undercounted in
    # HLO cost analysis — see EXPERIMENTS.md §Roofline)
    flops = cell.get("analytic_flops_global", 0.0) / devices \
        if cell.get("analytic_flops_global") else corr.get(
            "flops", cell.get("flops", 0.0))
    flops_hlo = corr.get("flops", cell.get("flops", 0.0))
    flops = max(flops, flops_hlo)
    bts = corr.get("bytes", cell.get("bytes", 0.0)) \
        + cell.get("attn_hbm_topup_global", 0.0) / devices
    coll = sum(corr.get("collective_bytes",
                        cell.get("collective_bytes", {})).values())
    t_c = flops / PEAK_FLOPS
    t_m = bts / HBM_BW
    t_n = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_n}
    bottleneck = max(terms, key=terms.get)
    out = dict(cell)
    out.update(t_compute=t_c, t_memory=t_m, t_collective=t_n,
               bottleneck=bottleneck,
               bound_time=max(t_c, t_m, t_n))
    if cell.get("n_params"):
        mult = 6.0 if cell.get("kind") == "train" else 2.0
        model_flops = mult * cell["n_active_params"] * cell["tokens"]
        per_dev = model_flops / devices
        out["model_flops_per_dev"] = per_dev
        out["useful_ratio"] = per_dev / flops if flops else 0.0
        out["mfu_bound"] = (per_dev / PEAK_FLOPS) / out["bound_time"] \
            if out["bound_time"] else 0.0
    return out


def load_cells(dryrun_dir: str = "results/dryrun"):
    cells = []
    for p in sorted(Path(dryrun_dir).glob("*.json")):
        c = json.loads(p.read_text())
        if "error" in c or c.get("skipped"):
            cells.append(c)
            continue
        cells.append(analyse(c))
    return cells


def render_table(cells, mesh: str = "single") -> str:
    rows = []
    hdr = (f"{'arch':22s} {'shape':12s} {'comp(ms)':>9s} {'mem(ms)':>9s} "
           f"{'coll(ms)':>9s} {'bound':>10s} {'useful':>7s} {'roofMFU':>8s}")
    rows.append(hdr)
    rows.append("-" * len(hdr))
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if c.get("skipped"):
            rows.append(f"{c['arch']:22s} {c['shape']:12s} "
                        f"{'—  skipped: sub-quadratic required':>40s}")
            continue
        if "error" in c:
            rows.append(f"{c['arch']:22s} {c['shape']:12s}  ERROR")
            continue
        rows.append(
            f"{c['arch']:22s} {c['shape']:12s} "
            f"{c['t_compute']*1e3:9.2f} {c['t_memory']*1e3:9.2f} "
            f"{c['t_collective']*1e3:9.2f} {c['bottleneck']:>10s} "
            f"{c.get('useful_ratio', 0)*100:6.1f}% "
            f"{c.get('mfu_bound', 0)*100:7.1f}%")
    return "\n".join(rows)


def add_args(ap) -> None:
    ap.add_argument("--dryrun-dir", default="results/dryrun",
                    help="directory of dry-run artifact JSONs "
                         "(python -m repro.launch.dryrun --all)")


def run_bench(args) -> BenchReport:
    """Contract entry: analyse the dry-run artifacts when present (the
    report is empty — not an error — when none exist yet)."""
    rep = BenchReport("roofline", meta={"params": {
        "dryrun_dir": args.dryrun_dir}})
    if not Path(args.dryrun_dir).exists() or \
            not any(Path(args.dryrun_dir).glob("*.json")):
        print(f"(no dry-run artifacts under {args.dryrun_dir}; run "
              f"`python -m repro.launch.dryrun --all` first)")
        rep.meta["skipped"] = "no dry-run artifacts"
        return rep
    cells = load_cells(args.dryrun_dir)
    print("\n=== roofline (single-pod) ===")
    print(render_table(cells, "single"))
    print("\n=== multi-pod (2x16x16): compile-proof cells ===")
    print("(probe-corrected costs are reported single-pod per the "
          "assignment; multi-pod cells prove the 'pod' axis shards — "
          "raw HLO numbers below are scan-undercounted, see EXPERIMENTS)")
    print(render_table(cells, "multi"))
    rep.raw = {"cells": cells}
    for c in cells:
        if c.get("skipped") or "error" in c or not c.get("arch"):
            continue
        key = f"roofline.{c['arch']}.{c.get('shape', '')}.{c.get('mesh', '')}"
        rep.add(f"{key}.mfu_bound", round(c.get("mfu_bound", 0.0), 4),
                unit="ratio", direction="higher", gate=False,
                tags={"bottleneck": c.get("bottleneck", "?")})
    return rep


BENCH = Benchmark(
    area="roofline",
    title="Roofline analysis over the dry-run compile artifacts",
    add_args=add_args,
    run=run_bench,
    gated=False,
)


def main(argv=None) -> BenchReport:
    return bench_main(BENCH, argv)


if __name__ == "__main__":
    main()
