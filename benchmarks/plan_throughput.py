"""Execution-plan benchmarks: vectorized trace synthesis + planned sweeps.

    PYTHONPATH=src python benchmarks/plan_throughput.py

Part 1 — trace synthesis at scale (the ROADMAP ">100k-core trace synthesis
dominates sweep setup" item): times the vectorized ``app_trace`` at the
target mesh (default 256x256 = 65,536 cores) against the historical
per-node-loop generator ``app_trace_loop`` (timed at a smaller mesh and
extrapolated linearly — the loop *is* linear in nodes — unless
``--full-loop`` is given), and reports trace synthesis as a fraction of
end-to-end setup (synthesis + state init).

Part 2 — planned mixed-shape sweep: a manifest mixing two mesh shapes runs
through ``compile_plan``/``execute_plan`` (one compiled program per shape
bucket) vs the same scenarios as sequential solo ``run()`` calls, with a
bit-exactness cross-check, so no speedup is ever bought with wrong numbers.

Part 3 — backend shoot-out: ONE bucket (B scenarios of one mesh shape)
forced through each backend — vmapped ``sweep``, spatial ``sharded``
(B sequential spatial runs), composed ``scenario x row x col`` — on this
host's devices, with wall-clock per backend, the planner's own pick, and
a cross-backend bit-equality check.  Backends that are structurally
impossible here (one device, indivisible mesh) degrade to ``sweep`` and
are reported with the planner's note.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

from repro.core import engine                              # noqa: E402

engine.expose_host_devices()   # before anything imports jax

from repro.core.config import SimConfig                    # noqa: E402
from repro.core.sim import run                             # noqa: E402
from repro.core.state import init_state                    # noqa: E402
from repro.core.trace import (                             # noqa: E402
    app_trace, app_trace_loop, resolve_trace)


def bench_trace(args) -> dict:
    cfg = SimConfig(rows=args.trace_rows, cols=args.trace_cols,
                    centralized_directory=False)
    t0 = time.time()
    tr = app_trace(cfg, args.trace_app, args.trace_refs, seed=0)
    vec_s = time.time() - t0

    t0 = time.time()
    s = init_state(cfg, tr)
    s.st.block_until_ready()
    init_s = time.time() - t0

    if args.full_loop:
        loop_cfg, scale = cfg, 1.0
    else:
        loop_cfg = SimConfig(rows=args.loop_rows, cols=args.loop_cols,
                             centralized_directory=False)
        scale = cfg.num_nodes / loop_cfg.num_nodes
    t0 = time.time()
    app_trace_loop(loop_cfg, args.trace_app, args.trace_refs, seed=0)
    loop_s = (time.time() - t0) * scale

    return {
        "nodes": cfg.num_nodes,
        "refs_per_core": args.trace_refs,
        "vectorized_synth_s": round(vec_s, 3),
        "loop_synth_s" + ("" if args.full_loop else "_extrapolated"):
            round(loop_s, 3),
        "synth_speedup": round(loop_s / vec_s, 1),
        "state_init_s": round(init_s, 3),
        "trace_fraction_of_setup": round(vec_s / (vec_s + init_s), 3),
        "loop_trace_fraction_of_setup": round(loop_s / (loop_s + init_s), 3),
    }


def bench_plan(args) -> dict:
    base = SimConfig(centralized_directory=False, max_cycles=args.max_cycles)
    seeds = range(args.seeds_per_shape)
    scenarios = [engine.make_scenario(base, r, c, args.app, s, args.refs)
                 for (r, c) in ((args.rows_a, args.cols_a),
                                (args.rows_b, args.cols_b))
                 for s in seeds]

    t0 = time.time()
    ref = []
    for sc in scenarios:
        tr = resolve_trace(sc.cfg, sc.app, sc.refs_per_core, sc.seed)
        ref.append(run(sc.cfg, tr, chunk=args.chunk))
    seq_s = time.time() - t0

    plan = engine.compile_plan(scenarios)
    t0 = time.time()
    got = engine.execute_plan(plan, chunk=args.chunk)
    plan_s = time.time() - t0
    mismatches = [i for i, (a, b) in enumerate(zip(ref, got)) if a != b]

    return {
        "plan": plan.describe(),
        "n_scenarios": len(scenarios),
        "bit_identical": not mismatches,
        "mismatched_scenarios": mismatches,
        "sequential_s": round(seq_s, 2),
        "planned_s": round(plan_s, 2),
        "speedup": round(seq_s / plan_s, 2),
        "all_finished": all(r.get("finished") for r in got),
    }


def bench_backends(args) -> dict:
    """Force one bucket through sweep / sharded / composed and compare."""
    import jax
    base = SimConfig(rows=args.bk_rows, cols=args.bk_cols,
                     centralized_directory=False,
                     max_cycles=args.max_cycles)
    scenarios = [engine.make_scenario(base, app=args.app, seed=s,
                                      refs_per_core=args.refs)
                 for s in range(args.bk_batch)]
    out = {"rows": args.bk_rows, "cols": args.bk_cols,
           "batch": args.bk_batch, "devices": len(jax.devices())}
    results = {}
    for force in ("sweep", "sharded", "composed"):
        if force == "sharded":
            # sharded has no batch axis: B sequential spatial plans
            plans = [engine.compile_plan([sc], force_backend="sharded")
                     for sc in scenarios]
        else:
            plans = [engine.compile_plan(scenarios, force_backend=force)]
        # warm compile caches out of the timed region
        for p in plans:
            engine.execute_plan(p, chunk=args.chunk,
                                sharded_chunk=args.sharded_chunk)
        t0 = time.time()
        res = []
        for p in plans:
            res.extend(engine.execute_plan(p, chunk=args.chunk,
                                           sharded_chunk=args.sharded_chunk))
        dt = time.time() - t0
        b0 = plans[0].buckets[0]
        out[force] = {
            "wall_s": round(dt, 2),
            "scenarios_per_sec": round(len(scenarios) / dt, 3),
            "effective_backend": b0.backend,
            **({"grid": list(b0.grid)} if b0.backend != "sweep" else {}),
            **({"note": b0.note} if b0.note else {}),
        }
        results[force] = res
    auto = engine.compile_plan(scenarios).buckets[0]
    out["planner_pick"] = auto.backend
    # sharded runs with dir_layout="home"; healthy stats are still
    # bit-identical across backends, which is the point of the check
    out["bit_identical_across_backends"] = (
        results["sweep"] == results["sharded"] == results["composed"])
    return out


def bench_wedge(args) -> dict:
    """The former S14 ejection-bar wedge (ROADMAP: 16x16 / matmul / seed 0
    / refs 20, loop-trace generator) as a tracked scenario: with the
    pending-completion queue it *completes*, so the perf trajectory now
    records its completion time (cycles + wall) instead of an abort time.
    The pc_depth=1 escape hatch is timed next to it for the abort
    baseline."""
    cfg = SimConfig(rows=16, cols=16, centralized_directory=False,
                    max_cycles=args.max_cycles)
    sc = engine.make_scenario(cfg, app="loop:matmul", seed=0,
                              refs_per_core=20)
    plan = engine.compile_plan([sc])
    engine.execute_plan(plan, chunk=16)          # warm the compile cache
    t0 = time.time()
    (st,) = engine.execute_plan(plan, chunk=16)
    wall = time.time() - t0

    import dataclasses
    cfg1 = dataclasses.replace(cfg, pc_depth=1, livelock_window=256)
    tr = app_trace_loop(cfg1, "matmul", 20, 0)
    run(cfg1, tr, chunk=16)                      # warm
    t0 = time.time()
    st1 = run(cfg1, tr, chunk=16)
    wall1 = time.time() - t0

    return {
        "scenario": "16x16/loop:matmul/seed0/refs20 (former ROADMAP wedge)",
        "finished": bool(st.get("finished")),
        "completion_cycles": st.get("cycles"),
        "completion_wall_s": round(wall, 2),
        "send_drops_recovered": st.get("send_drop"),
        "stray_responses": st.get("stray"),
        "pc_depth_1_baseline": {
            "aborted": st1.get("aborted"),
            "abort_cycles": st1.get("cycles"),
            "abort_wall_s": round(wall1, 2),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-rows", type=int, default=256)
    ap.add_argument("--trace-cols", type=int, default=256)
    ap.add_argument("--trace-refs", type=int, default=200)
    ap.add_argument("--trace-app", default="matmul")
    ap.add_argument("--loop-rows", type=int, default=64)
    ap.add_argument("--loop-cols", type=int, default=64)
    ap.add_argument("--full-loop", action="store_true",
                    help="time the loop generator at the full target mesh "
                         "instead of extrapolating from --loop-rows/cols")
    ap.add_argument("--skip-plan", action="store_true")
    ap.add_argument("--skip-backends", action="store_true")
    ap.add_argument("--skip-wedge", action="store_true")
    ap.add_argument("--bk-rows", type=int, default=16)
    ap.add_argument("--bk-cols", type=int, default=16)
    ap.add_argument("--bk-batch", type=int, default=4,
                    help="scenarios in the backend shoot-out bucket")
    ap.add_argument("--sharded-chunk", type=int, default=64)
    ap.add_argument("--rows-a", type=int, default=8)
    ap.add_argument("--cols-a", type=int, default=8)
    ap.add_argument("--rows-b", type=int, default=16)
    ap.add_argument("--cols-b", type=int, default=16)
    ap.add_argument("--seeds-per-shape", type=int, default=3)
    ap.add_argument("--app", default="equake",
                    help="equake/refs=25 is verified deadlock-free at 16x16")
    ap.add_argument("--refs", type=int, default=25)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--max-cycles", type=int, default=20_000)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    payload = {"trace_synthesis": bench_trace(args)}
    if not args.skip_plan:
        payload["planned_sweep"] = bench_plan(args)
    if not args.skip_backends:
        payload["backend_shootout"] = bench_backends(args)
    if not args.skip_wedge:
        payload["livelock_wedge"] = bench_wedge(args)
    print(json.dumps(payload, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f)
    if not args.skip_plan and payload["planned_sweep"]["mismatched_scenarios"]:
        raise SystemExit("planned sweep diverged from sequential runs")
    if not args.skip_backends and \
            not payload["backend_shootout"]["bit_identical_across_backends"]:
        raise SystemExit("backends diverged on the same scenarios")
    if not args.skip_wedge and not payload["livelock_wedge"]["finished"]:
        raise SystemExit("former wedge scenario no longer completes")


if __name__ == "__main__":
    main()
