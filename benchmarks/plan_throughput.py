"""Execution-plan benchmarks: planned sweeps, backend shoot-out, wedge.

    PYTHONPATH=src python benchmarks/plan_throughput.py [--smoke] [--out f]

Part 1 — planned mixed-shape sweep: a manifest mixing two mesh shapes runs
through ``compile_plan``/``execute_plan`` (one compiled program per shape
bucket) vs the same scenarios as sequential solo ``run()`` calls, with a
bit-exactness cross-check, so no speedup is ever bought with wrong numbers.

Part 2 — backend shoot-out: ONE bucket (B scenarios of one mesh shape)
forced through each backend — vmapped ``sweep``, spatial ``sharded``
(B sequential spatial runs), composed ``scenario x row x col`` — on this
host's devices, with wall-clock per backend, the planner's own pick, and
a cross-backend bit-equality check.  Backends that are structurally
impossible here (one device, indivisible mesh) degrade to ``sweep`` and
are reported with the planner's note.

Part 3 — the former S14 ejection-bar wedge (16x16 / loop:matmul / seed 0
/ refs 20) as a tracked scenario: its completion cycles/time and drop
counts are gated so the livelock fix can never silently rot.

Emits ``BENCH_plan.json``: gated metrics are the deterministic counters
(bucket/compile counts, wedge completion cycles, drops) plus the
plan-vs-sequential speedup ratio; raw walls and per-backend
scenarios/sec ride along ungated.  (Trace synthesis moved to
``benchmarks/trace_throughput.py`` / ``BENCH_trace.json``.)
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

from repro.core import engine                              # noqa: E402

engine.expose_host_devices()   # before anything imports jax

from repro.bench import BenchReport, Benchmark, bench_main  # noqa: E402
from repro.bench.collect import (                           # noqa: E402
    count_metric, flag_metric, health_metrics, ratio_metric, timing_metric)
from repro.core import SimConfig, run                       # noqa: E402
from repro.core.trace import app_trace_loop, resolve_trace  # noqa: E402


def bench_plan(args) -> dict:
    base = SimConfig(centralized_directory=False, max_cycles=args.max_cycles)
    seeds = range(args.seeds_per_shape)
    scenarios = [engine.make_scenario(base, r, c, args.app, s, args.refs)
                 for (r, c) in ((args.rows_a, args.cols_a),
                                (args.rows_b, args.cols_b))
                 for s in seeds]

    t0 = time.time()
    ref = []
    for sc in scenarios:
        tr = resolve_trace(sc.cfg, sc.app, sc.refs_per_core, sc.seed)
        ref.append(run(sc.cfg, tr, chunk=args.chunk))
    seq_s = time.time() - t0

    plan = engine.compile_plan(scenarios)
    t0 = time.time()
    got = engine.execute_plan(plan, chunk=args.chunk)
    plan_s = time.time() - t0
    mismatches = [i for i, (a, b) in enumerate(zip(ref, got)) if a != b]

    return {
        "plan": plan.describe(),
        "n_scenarios": len(scenarios),
        "bit_identical": not mismatches,
        "mismatched_scenarios": mismatches,
        "sequential_s": round(seq_s, 2),
        "planned_s": round(plan_s, 2),
        "speedup": round(seq_s / plan_s, 2),
        "all_finished": all(r.get("finished") for r in got),
        "scenario_stats": got,
    }


def bench_backends(args) -> dict:
    """Force one bucket through sweep / sharded / composed and compare."""
    import jax
    base = SimConfig(rows=args.bk_rows, cols=args.bk_cols,
                     centralized_directory=False,
                     max_cycles=args.max_cycles)
    scenarios = [engine.make_scenario(base, app=args.app, seed=s,
                                      refs_per_core=args.refs)
                 for s in range(args.bk_batch)]
    out = {"rows": args.bk_rows, "cols": args.bk_cols,
           "batch": args.bk_batch, "devices": len(jax.devices())}
    results = {}
    for force in ("sweep", "sharded", "composed"):
        if force == "sharded":
            # sharded has no batch axis: B sequential spatial plans
            plans = [engine.compile_plan([sc], force_backend="sharded")
                     for sc in scenarios]
        else:
            plans = [engine.compile_plan(scenarios, force_backend=force)]
        # warm compile caches out of the timed region
        for p in plans:
            engine.execute_plan(p, chunk=args.chunk,
                                sharded_chunk=args.sharded_chunk)
        t0 = time.time()
        res = []
        for p in plans:
            res.extend(engine.execute_plan(p, chunk=args.chunk,
                                           sharded_chunk=args.sharded_chunk))
        dt = time.time() - t0
        b0 = plans[0].buckets[0]
        out[force] = {
            "wall_s": round(dt, 2),
            "scenarios_per_sec": round(len(scenarios) / dt, 3),
            "effective_backend": b0.backend,
            **({"grid": list(b0.grid)} if b0.backend != "sweep" else {}),
            **({"note": b0.note} if b0.note else {}),
        }
        results[force] = res
    auto = engine.compile_plan(scenarios).buckets[0]
    out["planner_pick"] = auto.backend
    # sharded runs with dir_layout="home"; healthy stats are still
    # bit-identical across backends, which is the point of the check
    out["bit_identical_across_backends"] = (
        results["sweep"] == results["sharded"] == results["composed"])
    return out


def bench_wedge(args) -> dict:
    """The former S14 ejection-bar wedge (ROADMAP: 16x16 / matmul / seed 0
    / refs 20, loop-trace generator) as a tracked scenario: with the
    pending-completion queue it *completes*, so the perf trajectory now
    records its completion time (cycles + wall) instead of an abort time.
    The pc_depth=1 escape hatch is timed next to it for the abort
    baseline."""
    cfg = SimConfig(rows=16, cols=16, centralized_directory=False,
                    max_cycles=max(args.max_cycles, 200_000))
    sc = engine.make_scenario(cfg, app="loop:matmul", seed=0,
                              refs_per_core=20)
    plan = engine.compile_plan([sc])
    engine.execute_plan(plan, chunk=16)          # warm the compile cache
    t0 = time.time()
    (st,) = engine.execute_plan(plan, chunk=16)
    wall = time.time() - t0

    import dataclasses
    cfg1 = dataclasses.replace(cfg, pc_depth=1, livelock_window=256,
                               max_cycles=30_000)
    tr = app_trace_loop(cfg1, "matmul", 20, 0)
    run(cfg1, tr, chunk=16)                      # warm
    t0 = time.time()
    st1 = run(cfg1, tr, chunk=16)
    wall1 = time.time() - t0

    return {
        "scenario": "16x16/loop:matmul/seed0/refs20 (former ROADMAP wedge)",
        "finished": bool(st.get("finished")),
        "completion_cycles": st.get("cycles"),
        "completion_wall_s": round(wall, 2),
        "send_drops_recovered": st.get("send_drop"),
        "stray_responses": st.get("stray"),
        "stats": st,
        "pc_depth_1_baseline": {
            "aborted": st1.get("aborted"),
            "abort_cycles": st1.get("cycles"),
            "abort_wall_s": round(wall1, 2),
        },
    }


def add_args(ap) -> None:
    ap.add_argument("--skip-plan", action="store_true")
    ap.add_argument("--skip-backends", action="store_true")
    ap.add_argument("--skip-wedge", action="store_true")
    ap.add_argument("--bk-rows", type=int, default=16)
    ap.add_argument("--bk-cols", type=int, default=16)
    ap.add_argument("--bk-batch", type=int, default=4,
                    help="scenarios in the backend shoot-out bucket")
    ap.add_argument("--sharded-chunk", type=int, default=64)
    ap.add_argument("--rows-a", type=int, default=8)
    ap.add_argument("--cols-a", type=int, default=8)
    ap.add_argument("--rows-b", type=int, default=16)
    ap.add_argument("--cols-b", type=int, default=16)
    ap.add_argument("--seeds-per-shape", type=int, default=3)
    ap.add_argument("--app", default="equake",
                    help="equake/refs=25 is verified deadlock-free at 16x16")
    ap.add_argument("--refs", type=int, default=25)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--max-cycles", type=int, default=20_000)


def run_bench(args) -> BenchReport:
    """Contract entry: run the three parts, emit ``BENCH_plan.json``
    metrics, and hard-fail on any cross-check divergence."""
    rep = BenchReport("plan", meta={"params": {
        "shapes": [f"{args.rows_a}x{args.cols_a}",
                   f"{args.rows_b}x{args.cols_b}"],
        "seeds_per_shape": args.seeds_per_shape, "app": args.app,
        "refs": args.refs, "bk_batch": args.bk_batch,
        "bk_mesh": f"{args.bk_rows}x{args.bk_cols}"}})

    if not args.skip_plan:
        p = bench_plan(args)
        stats = p.pop("scenario_stats")
        rep.raw["planned_sweep"] = p
        tags = {"app": args.app}
        rep.extend([
            count_metric("plan.n_scenarios", p["n_scenarios"],
                         direction="higher", tags=tags),
            count_metric("plan.n_buckets", p["plan"]["n_buckets"],
                         unit="compiles", tags=tags),
            flag_metric("plan.bit_identical", p["bit_identical"]),
            flag_metric("plan.all_finished", p["all_finished"]),
            timing_metric("plan.sequential_s", p["sequential_s"]),
            timing_metric("plan.planned_s", p["planned_s"]),
            ratio_metric("plan.speedup", p["speedup"], tags=tags),
        ])
        rep.extend(health_metrics(stats, "plan.net", tags=tags))

    if not args.skip_backends:
        b = bench_backends(args)
        rep.raw["backend_shootout"] = b
        tags = {"mesh": f"{args.bk_rows}x{args.bk_cols}",
                "batch": str(args.bk_batch)}
        for backend in ("sweep", "sharded", "composed"):
            rep.add(f"plan.backend.{backend}.scenarios_per_sec",
                    b[backend]["scenarios_per_sec"], unit="scen/s",
                    direction="higher", gate=False,
                    tags={**tags,
                          "effective": b[backend]["effective_backend"]})
        rep.extend([
            flag_metric("plan.backend.bit_identical",
                        b["bit_identical_across_backends"]),
            count_metric("plan.backend.devices", b["devices"],
                         unit="devices", direction="higher", gate=False),
        ])

    if not args.skip_wedge:
        w = bench_wedge(args)
        wstats = w.pop("stats")
        rep.raw["livelock_wedge"] = w
        tags = {"scenario": "16x16/loop:matmul/0/20"}
        rep.extend([
            flag_metric("plan.wedge.finished", w["finished"], tags=tags),
            count_metric("plan.wedge.completion_cycles",
                         w["completion_cycles"], unit="cycles", tags=tags),
            timing_metric("plan.wedge.completion_wall_s",
                          w["completion_wall_s"], tags=tags),
        ])
        rep.extend(health_metrics([wstats], "plan.wedge.net", tags=tags))

    if not args.skip_plan and \
            rep.raw["planned_sweep"]["mismatched_scenarios"]:
        raise SystemExit("planned sweep diverged from sequential runs")
    if not args.skip_backends and \
            not rep.raw["backend_shootout"]["bit_identical_across_backends"]:
        raise SystemExit("backends diverged on the same scenarios")
    if not args.skip_wedge and not rep.raw["livelock_wedge"]["finished"]:
        raise SystemExit("former wedge scenario no longer completes")
    return rep


BENCH = Benchmark(
    area="plan",
    title="Execution-plan layer: mixed-shape sweep, backend shoot-out, "
          "wedge completion",
    add_args=add_args,
    run=run_bench,
    smoke={"rows_a": 4, "cols_a": 4, "rows_b": 8, "cols_b": 8,
           "seeds_per_shape": 2, "refs": 10, "bk_rows": 8, "bk_cols": 8,
           "bk_batch": 2},
)


def main(argv=None) -> BenchReport:
    return bench_main(BENCH, argv)


if __name__ == "__main__":
    main()
