"""Sweep-engine throughput: batched ``run_sweep`` vs a sequential ``run()`` loop.

    PYTHONPATH=src python benchmarks/sweep_throughput.py [--smoke] [--out f]

The default scenario set is a *deflection-policy sweep* (the realistic
use of a sweep engine, cf. the Ausavarungnirun-style studies): every
scenario carries a distinct (migration on/off, migrate-threshold,
centralized/distributed directory) policy.  Policy knobs are *static*
jit arguments on the solo path, so the sequential loop pays one fresh
XLA compile per distinct policy plus one device-loop dispatch per
scenario; ``run_sweep`` carries the knobs as traced per-scenario state
and pays ONE compile and ONE device loop for the whole batch.

Reported numbers:
  * cold_*: end-to-end sweep latency including compilation — the
    headline metric (a sweep is a one-shot batch job; this is what a
    user waits for, and it is where the engine's one-program design
    pays off).
  * warm_*: steady-state loop-only throughput with all compile caches
    hot.  The sweep shards its scenario axis over every core (exposed
    as XLA host devices), so the one compiled program fills the machine
    while the sequential loop runs one scenario at a time; on wide
    accelerators the same batch rides the hardware's parallel width.

The run also cross-checks that batched stats are bit-identical to the
sequential ones, so no speedup is ever bought with wrong numbers.

Emits ``BENCH_sweep.json``: gated metrics are the cold/warm speedup
ratios, the compile counts (distinct configs) and the deterministic
cycle/health counters; raw walls and scenarios/sec ride along ungated.
"""
from __future__ import annotations

import dataclasses
import sys
import time

sys.path.insert(0, "src")

# expose every core as an XLA host device BEFORE jax loads: run_sweep
# shards the scenario axis across them, so the one compiled program fills
# the machine (the sequential baseline keeps its usual single device)
from repro.core.engine import expose_host_devices          # noqa: E402

expose_host_devices()

from repro.bench import BenchReport, Benchmark, bench_main  # noqa: E402
from repro.bench.collect import (                           # noqa: E402
    count_metric, flag_metric, health_metrics, ratio_metric, timing_metric)
from repro.core import SimConfig                            # noqa: E402
from repro.core.sweep import (                              # noqa: E402
    ScenarioSpec, SweepSpec, run_sequential, run_sweep)


def policy_axis(n: int):
    """Migration-policy sensitivity axis: base, migration-off, then a
    fine-grained threshold scan — ``n`` *distinct* SimConfigs, i.e. ``n``
    fresh compiles on the solo path (policy knobs are static jit args
    there; the sweep engine carries them as traced state).  (A
    centralized-directory point is deliberately absent: at 256 nodes the
    node-0 hotspot blows past max_cycles, as the paper itself observes.)
    """
    pols = [dict(), dict(migration_enabled=False)]     # base: mig on, thr 3
    thr = 1
    while len(pols) < n:
        if thr != 3:                                   # 3 == base threshold
            pols.append(dict(migrate_threshold=thr))
        thr += 1
    return tuple(pols[:n])


def build_spec(cfg: SimConfig, apps, seeds, refs: int,
               n_policies: int) -> SweepSpec:
    if n_policies <= 0:
        return SweepSpec.cross(cfg, apps, seeds, refs)
    scenarios = tuple(
        ScenarioSpec(apps[i % len(apps)], seeds[i % len(seeds)], refs, **pol)
        for i, pol in enumerate(policy_axis(n_policies)))
    return SweepSpec(cfg, scenarios)


def add_args(ap) -> None:
    ap.add_argument("--rows", type=int, default=16)
    ap.add_argument("--cols", type=int, default=16)
    # default workload: one app so scenario lengths are near-uniform (the
    # batch finishes at max-of-B cycles; a straggler app would stretch it)
    # — pure policy sensitivity sweeps are the canonical use anyway.
    # equake/refs=25 is verified deadlock-free at 16x16 (see ROADMAP on
    # the protocol deadlock some (cfg, trace) combos hit).
    ap.add_argument("--apps", default="equake")
    ap.add_argument("--seeds", default="0,1")
    ap.add_argument("--refs", type=int, default=25)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--max-cycles", type=int, default=20_000,
                    help="per-scenario cycle cap: bounds the cost of a "
                         "deadlocked/saturated scenario in BOTH paths")
    ap.add_argument("--n-policies", type=int, default=32,
                    help="size of the policy sensitivity axis; 0 = plain "
                         "apps x seeds sweep with one shared policy")


def run_bench(args) -> BenchReport:
    """Contract entry: cold + warm sequential-vs-sweep comparison with
    the bit-exactness cross-check; emits ``BENCH_sweep.json`` metrics."""
    cfg = SimConfig(rows=args.rows, cols=args.cols,
                    centralized_directory=False)
    cfg = dataclasses.replace(cfg, max_cycles=args.max_cycles)
    spec = build_spec(cfg, args.apps.split(","),
                      [int(x) for x in args.seeds.split(",")],
                      args.refs, n_policies=args.n_policies)
    n_cfgs = len({sc.resolve_cfg(cfg) for sc in spec.scenarios})

    # cold: first call of each path compiles (the two paths use disjoint
    # jit cache entries — batched state shapes differ from solo ones)
    t0 = time.time()
    ref = run_sequential(spec, chunk=args.chunk)
    cold_seq = time.time() - t0
    t0 = time.time()
    got = run_sweep(spec, chunk=args.chunk)
    cold_sweep = time.time() - t0
    mismatches = [i for i, (a, b) in enumerate(zip(ref, got)) if a != b]

    # warm: loop-only, all compiles cached
    t0 = time.time()
    run_sequential(spec, chunk=args.chunk)
    warm_seq = time.time() - t0
    t0 = time.time()
    run_sweep(spec, chunk=args.chunk)
    warm_sweep = time.time() - t0

    raw = {
        "nodes": cfg.num_nodes,
        "n_scenarios": spec.size,
        "n_distinct_configs": n_cfgs,
        "refs_per_core": args.refs,
        "chunk": args.chunk,
        "bit_identical": not mismatches,
        "mismatched_scenarios": mismatches,
        "cold_sequential_s": round(cold_seq, 2),
        "cold_sweep_s": round(cold_sweep, 2),
        "cold_sequential_scenarios_per_sec": round(spec.size / cold_seq, 3),
        "cold_sweep_scenarios_per_sec": round(spec.size / cold_sweep, 3),
        "speedup": round(cold_seq / cold_sweep, 2),   # cold, end-to-end
        "warm_sequential_s": round(warm_seq, 2),
        "warm_sweep_s": round(warm_sweep, 2),
        "warm_speedup": round(warm_seq / warm_sweep, 2),
        "max_cycles_simulated": max(r["cycles"] for r in got),
        "all_finished": all(r["finished"] for r in got),
    }

    tags = {"mesh": f"{args.rows}x{args.cols}", "apps": args.apps}
    rep = BenchReport("sweep", meta={"params": {
        "refs": args.refs, "chunk": args.chunk,
        "n_policies": args.n_policies, "seeds": args.seeds}}, raw=raw)
    rep.extend([
        count_metric("sweep.n_scenarios", raw["n_scenarios"],
                     direction="higher", tags=tags),
        count_metric("sweep.n_distinct_configs", raw["n_distinct_configs"],
                     unit="compiles", direction="higher", tags=tags),
        flag_metric("sweep.bit_identical", raw["bit_identical"]),
        flag_metric("sweep.all_finished", raw["all_finished"]),
        ratio_metric("sweep.cold_speedup", raw["speedup"], tags=tags),
        ratio_metric("sweep.warm_speedup", raw["warm_speedup"], tags=tags),
        timing_metric("sweep.cold_sequential_s", raw["cold_sequential_s"]),
        timing_metric("sweep.cold_sweep_s", raw["cold_sweep_s"]),
        timing_metric("sweep.warm_sequential_s", raw["warm_sequential_s"]),
        timing_metric("sweep.warm_sweep_s", raw["warm_sweep_s"]),
        timing_metric("sweep.cold_scenarios_per_sec",
                      raw["cold_sweep_scenarios_per_sec"], unit="scen/s",
                      direction="higher", tags=tags),
        count_metric("sweep.max_cycles_simulated",
                     raw["max_cycles_simulated"], unit="cycles", tags=tags),
    ])
    rep.extend(health_metrics(got, "sweep.net", tags=tags))
    if mismatches:
        raise SystemExit("batched sweep diverged from sequential runs")
    return rep


BENCH = Benchmark(
    area="sweep",
    title="Batched policy sweep vs sequential solo loop (cold + warm)",
    add_args=add_args,
    run=run_bench,
    smoke={"rows": 8, "cols": 8, "refs": 15, "n_policies": 4},
)


def main(argv=None) -> BenchReport:
    return bench_main(BENCH, argv)


if __name__ == "__main__":
    main()
