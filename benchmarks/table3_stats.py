"""Paper Table 3: per-application traffic/cache statistics.

    PYTHONPATH=src python benchmarks/table3_stats.py [--smoke] [--out f]

The paper reports request/reply/trap/redirection/dir-search/memory counts
for 5 application traces at 10,000 simulated cores.  CPU budget here runs
the same table at a configurable mesh (default 16x16; pass --rows/--cols
for larger).  Every emitted metric is a deterministic counter, so the
area gates cleanly (zero slack) wherever a baseline is committed.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.bench import BenchReport, Benchmark, bench_main      # noqa: E402
from repro.bench.collect import health_metrics                  # noqa: E402
from repro.core import SimConfig, run                           # noqa: E402
from repro.core.trace import TRACE_APPS, app_trace              # noqa: E402

COLS = ("req_made", "req_rcvd", "reply_sent", "reply_rcvd", "trap",
        "redirection", "dir_search", "mem_req", "migrations")


def add_args(ap) -> None:
    ap.add_argument("--rows", type=int, default=16)
    ap.add_argument("--cols", type=int, default=16)
    ap.add_argument("--refs", type=int, default=100)


def run_bench(args) -> BenchReport:
    """Contract entry: the per-application statistics table."""
    results = {}
    print(f"{'app':10s} " + " ".join(f"{c:>10s}" for c in COLS))
    for app in TRACE_APPS:
        cfg = SimConfig(rows=args.rows, cols=args.cols, addr_bits=20,
                        centralized_directory=False, migrate_threshold=2)
        stats = run(cfg, app_trace(cfg, app, args.refs, seed=1), chunk=8)
        results[app] = stats
        print(f"{app:10s} " + " ".join(f"{stats[c]:>10d}" for c in COLS))
        assert stats["finished"] == 1, app
    rep = BenchReport("table3", meta={"params": {
        "mesh": f"{args.rows}x{args.cols}", "refs": args.refs}},
        raw=results)
    mesh = {"mesh": f"{args.rows}x{args.cols}"}
    for app, stats in results.items():
        rep.add(f"table3.{app}.cycles", stats["cycles"], unit="cycles",
                direction="lower", tags={**mesh, "app": app})
        rep.add(f"table3.{app}.traps", stats["trap"], unit="count",
                direction="lower", tags={**mesh, "app": app})
    rep.extend(health_metrics(list(results.values()), "table3.net",
                              tags=mesh))
    return rep


BENCH = Benchmark(
    area="table3",
    title="Paper Table 3: per-application traffic/cache statistics",
    add_args=add_args,
    run=run_bench,
    smoke={"rows": 8, "cols": 8, "refs": 60},
    gated=False,
)


def main(argv=None) -> BenchReport:
    return bench_main(BENCH, argv)


if __name__ == "__main__":
    main()
