"""Paper Table 3: per-application traffic/cache statistics.

The paper reports request/reply/trap/redirection/dir-search/memory counts
for 5 application traces at 10,000 simulated cores.  CPU budget here runs
the same table at a configurable mesh (default 16x16; pass --rows/--cols
for larger).
"""
from __future__ import annotations

import argparse
import json

from repro.core.config import SimConfig
from repro.core.sim import run
from repro.core.trace import TRACE_APPS, app_trace

COLS = ("req_made", "req_rcvd", "reply_sent", "reply_rcvd", "trap",
        "redirection", "dir_search", "mem_req", "migrations")


def main(rows: int = 16, cols: int = 16, refs: int = 100,
         out_json: str | None = None) -> dict:
    results = {}
    print(f"{'app':10s} " + " ".join(f"{c:>10s}" for c in COLS))
    for app in TRACE_APPS:
        cfg = SimConfig(rows=rows, cols=cols, addr_bits=20,
                        centralized_directory=False, migrate_threshold=2)
        stats = run(cfg, app_trace(cfg, app, refs, seed=1), chunk=8)
        results[app] = stats
        print(f"{app:10s} " + " ".join(f"{stats[c]:>10d}" for c in COLS))
        assert stats["finished"] == 1, app
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=16)
    ap.add_argument("--cols", type=int, default=16)
    ap.add_argument("--refs", type=int, default=100)
    ap.add_argument("--json", default=None)
    a = ap.parse_args()
    main(a.rows, a.cols, a.refs, a.json)
