"""Trace-synthesis throughput: vectorized ``app_trace`` vs the per-node loop.

    PYTHONPATH=src python benchmarks/trace_throughput.py [--smoke] [--out f]

Times the vectorized generator at the target mesh (default 256x256 =
65,536 cores) against the historical per-node-loop generator
``app_trace_loop`` (timed at a smaller mesh and extrapolated linearly —
the loop *is* linear in nodes — unless ``--full-loop`` is given), and
reports trace synthesis as a fraction of end-to-end setup (synthesis +
state init).  Emits the ``BENCH_trace.json`` report: the gated metric is
the synth *speedup* (a same-host ratio, portable across machines); raw
walls ride along ungated.
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

from repro.bench import BenchReport, Benchmark, bench_main      # noqa: E402
from repro.bench.collect import (                               # noqa: E402
    count_metric, ratio_metric, timing_metric)
from repro.core import SimConfig                                # noqa: E402
from repro.core.trace import app_trace, app_trace_loop          # noqa: E402


def add_args(ap) -> None:
    ap.add_argument("--trace-rows", type=int, default=256)
    ap.add_argument("--trace-cols", type=int, default=256)
    ap.add_argument("--trace-refs", type=int, default=200)
    ap.add_argument("--trace-app", default="matmul")
    ap.add_argument("--loop-rows", type=int, default=64)
    ap.add_argument("--loop-cols", type=int, default=64)
    ap.add_argument("--full-loop", action="store_true",
                    help="time the loop generator at the full target mesh "
                         "instead of extrapolating from --loop-rows/cols")


def bench_trace(args) -> dict:
    """The measurement (kept payload-shaped for reuse): vectorized synth
    at the ``args`` target mesh, loop synth (extrapolated), state init."""
    from repro.core.state import init_state
    cfg = SimConfig(rows=args.trace_rows, cols=args.trace_cols,
                    centralized_directory=False)
    t0 = time.time()
    tr = app_trace(cfg, args.trace_app, args.trace_refs, seed=0)
    vec_s = time.time() - t0

    t0 = time.time()
    s = init_state(cfg, tr)
    s.st.block_until_ready()
    init_s = time.time() - t0

    if args.full_loop:
        loop_cfg, scale = cfg, 1.0
    else:
        loop_cfg = SimConfig(rows=args.loop_rows, cols=args.loop_cols,
                             centralized_directory=False)
        scale = cfg.num_nodes / loop_cfg.num_nodes
    t0 = time.time()
    app_trace_loop(loop_cfg, args.trace_app, args.trace_refs, seed=0)
    loop_s = (time.time() - t0) * scale

    return {
        "nodes": cfg.num_nodes,
        "refs_per_core": args.trace_refs,
        "vectorized_synth_s": round(vec_s, 3),
        "loop_synth_s" + ("" if args.full_loop else "_extrapolated"):
            round(loop_s, 3),
        "synth_speedup": round(loop_s / vec_s, 1),
        "state_init_s": round(init_s, 3),
        "trace_fraction_of_setup": round(vec_s / (vec_s + init_s), 3),
        "loop_trace_fraction_of_setup": round(loop_s / (loop_s + init_s), 3),
    }


def run_bench(args) -> BenchReport:
    """Contract entry: run :func:`bench_trace`, emit the report."""
    raw = bench_trace(args)
    tags = {"mesh": f"{args.trace_rows}x{args.trace_cols}",
            "app": args.trace_app}
    rep = BenchReport("trace", meta={
        "params": {"refs": args.trace_refs,
                   "loop_mesh": f"{args.loop_rows}x{args.loop_cols}",
                   "full_loop": bool(args.full_loop)}}, raw=raw)
    rep.add("trace.nodes", raw["nodes"], unit="cores", direction="higher",
            tags=tags)
    rep.extend([
        # extra slack: the smoke-tier vectorized synth is ~0.05s, so the
        # ratio is noisy — the gate only needs to catch a collapse back
        # toward loop speed (speedup ~1), not a 30% wobble
        ratio_metric("trace.synth_speedup", raw["synth_speedup"],
                     slack=0.7, tags=tags),
        timing_metric("trace.vectorized_synth_s",
                      raw["vectorized_synth_s"], tags=tags),
        timing_metric("trace.state_init_s", raw["state_init_s"], tags=tags),
        timing_metric(
            "trace.refs_per_sec",
            raw["nodes"] * args.trace_refs / raw["vectorized_synth_s"],
            unit="refs/s", direction="higher", tags=tags),
        ratio_metric("trace.fraction_of_setup",
                     raw["trace_fraction_of_setup"], unit="ratio",
                     direction="lower", gate=False, tags=tags),
    ])
    return rep


BENCH = Benchmark(
    area="trace",
    title="Vectorized trace synthesis vs the per-node loop generator",
    add_args=add_args,
    run=run_bench,
    smoke={"trace_rows": 64, "trace_cols": 64, "trace_refs": 50,
           "loop_rows": 16, "loop_cols": 16},
)


def main(argv=None) -> BenchReport:
    return bench_main(BENCH, argv)


if __name__ == "__main__":
    main()
