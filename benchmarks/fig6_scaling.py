"""Paper Figure 6: serial vs parallel simulation wall time vs core count.

The paper: serial C++ grows rapidly with core count; the GPU version is
~25x faster at 2,000 cores.  Here: serial numpy golden model vs the
vectorized JAX simulator on the same host.  Trace length follows the paper
(N x M references, M fixed), so work grows with core count.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.config import SimConfig
from repro.core.ref_serial import SerialSim
from repro.core.sim import run
from repro.core.trace import app_trace


def one(rows: int, cols: int, refs: int, serial_limit: int):
    cfg = SimConfig(rows=rows, cols=cols, addr_bits=18,
                    centralized_directory=False)
    tr = app_trace(cfg, "matmul", refs, seed=1)
    n = cfg.num_nodes

    run(cfg, tr, chunk=8)                 # warm the compile cache
    t0 = time.time()
    stats = run(cfg, tr, chunk=8)
    t_vec = time.time() - t0

    t_ser = None
    if n <= serial_limit:
        t0 = time.time()
        SerialSim(cfg, tr).run()
        t_ser = time.time() - t0
    return {"cores": n, "cycles": stats["cycles"], "vector_s": round(t_vec, 2),
            "serial_s": round(t_ser, 2) if t_ser else None,
            "speedup": round(t_ser / t_vec, 1) if t_ser else None}


def main(sizes=((4, 4), (8, 8), (16, 16), (32, 32)), refs=50,
         serial_limit=300, out_json=None):
    rows = []
    print(f"{'cores':>7s} {'cycles':>8s} {'vector_s':>9s} {'serial_s':>9s} "
          f"{'speedup':>8s}")
    for r, c in sizes:
        res = one(r, c, refs, serial_limit)
        rows.append(res)
        print(f"{res['cores']:>7d} {res['cycles']:>8d} {res['vector_s']:>9.2f} "
              f"{res['serial_s'] if res['serial_s'] else '—':>9} "
              f"{res['speedup'] if res['speedup'] else '—':>8}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--refs", type=int, default=50)
    ap.add_argument("--serial-limit", type=int, default=300)
    ap.add_argument("--json", default=None)
    a = ap.parse_args()
    main(refs=a.refs, serial_limit=a.serial_limit, out_json=a.json)
