"""Paper Figure 6: serial vs parallel simulation wall time vs core count.

    PYTHONPATH=src python benchmarks/fig6_scaling.py [--smoke] [--out f]

The paper: serial C++ grows rapidly with core count; the GPU version is
~25x faster at 2,000 cores.  Here: serial numpy golden model vs the
vectorized JAX simulator on the same host.  Trace length follows the paper
(N x M references, M fixed), so work grows with core count.  The gated
metric per mesh size is the serial/vector *speedup* (same-host ratio)
plus the deterministic completion cycles; raw walls ride along ungated.
"""
from __future__ import annotations

import sys
import time

sys.path.insert(0, "src")

from repro.bench import BenchReport, Benchmark, bench_main      # noqa: E402
from repro.bench.collect import (                               # noqa: E402
    count_metric, ratio_metric, timing_metric)
from repro.core import SimConfig, run                           # noqa: E402
from repro.core.ref_serial import SerialSim                     # noqa: E402
from repro.core.trace import app_trace                          # noqa: E402


def one(rows: int, cols: int, refs: int, serial_limit: int):
    cfg = SimConfig(rows=rows, cols=cols, addr_bits=18,
                    centralized_directory=False)
    tr = app_trace(cfg, "matmul", refs, seed=1)
    n = cfg.num_nodes

    run(cfg, tr, chunk=8)                 # warm the compile cache
    t0 = time.time()
    stats = run(cfg, tr, chunk=8)
    t_vec = time.time() - t0

    t_ser = None
    if n <= serial_limit:
        t0 = time.time()
        SerialSim(cfg, tr).run()
        t_ser = time.time() - t0
    return {"cores": n, "cycles": stats["cycles"], "vector_s": round(t_vec, 2),
            "serial_s": round(t_ser, 2) if t_ser else None,
            "speedup": round(t_ser / t_vec, 1) if t_ser else None}


def parse_sizes(text: str):
    """``"4x4,8x8"`` → ``[(4, 4), (8, 8)]``."""
    out = []
    for item in text.split(","):
        r, c = item.lower().split("x")
        out.append((int(r), int(c)))
    return out


def add_args(ap) -> None:
    ap.add_argument("--sizes", default="4x4,8x8,16x16,32x32",
                    help="comma list of ROWSxCOLS mesh sizes to scale over")
    ap.add_argument("--refs", type=int, default=50)
    ap.add_argument("--serial-limit", type=int, default=300,
                    help="skip the serial golden model above this many cores")


def run_bench(args) -> BenchReport:
    """Contract entry: one row per mesh size, serial-vs-vector."""
    rows = []
    print(f"{'cores':>7s} {'cycles':>8s} {'vector_s':>9s} {'serial_s':>9s} "
          f"{'speedup':>8s}")
    for r, c in parse_sizes(args.sizes):
        res = one(r, c, args.refs, args.serial_limit)
        rows.append(res)
        print(f"{res['cores']:>7d} {res['cycles']:>8d} "
              f"{res['vector_s']:>9.2f} "
              f"{res['serial_s'] if res['serial_s'] else '—':>9} "
              f"{res['speedup'] if res['speedup'] else '—':>8}")
    rep = BenchReport("fig6", meta={"params": {
        "sizes": args.sizes, "refs": args.refs,
        "serial_limit": args.serial_limit}}, raw={"rows": rows})
    for res in rows:
        tag = {"cores": str(res["cores"])}
        rep.extend([
            count_metric(f"fig6.{res['cores']}.cycles", res["cycles"],
                         unit="cycles", tags=tag),
            timing_metric(f"fig6.{res['cores']}.vector_s", res["vector_s"],
                          tags=tag),
        ])
        if res["speedup"]:
            rep.extend([ratio_metric(f"fig6.{res['cores']}.speedup",
                                     res["speedup"], tags=tag)])
    return rep


BENCH = Benchmark(
    area="fig6",
    title="Paper Fig. 6: serial golden model vs vectorized sim scaling",
    add_args=add_args,
    run=run_bench,
    smoke={"sizes": "4x4,8x8", "refs": 30},
    gated=False,
)


def main(argv=None) -> BenchReport:
    return bench_main(BENCH, argv)


if __name__ == "__main__":
    main()
