"""Calibrate the planner's cost-model constants on the actual host.

    PYTHONPATH=src python benchmarks/calibrate_cost_model.py --emit cost_model.json

The execution-plan layer (``repro.core.engine``) costs its three backends
with three constants — ``halo_overhead``, ``shard_fixed`` and
``batch_fixed`` (see :class:`repro.core.engine.CostConstants`).  The
shipped defaults are CPU-calibrated guesses; this harness *measures* them
by timing the real compiled step programs:

1. **dense** — the vmapped driver (``sim._run_jit``) at two mesh sizes
   gives the per-node-cycle unit cost the whole model is denominated in.
2. **sharded** — the spatial ``shard_map`` step at the same two mesh
   sizes and a fixed tile count: per-cycle time is
   ``(n/tiles * halo_overhead + shard_fixed) * unit``, linear in ``n``,
   so the slope yields ``halo_overhead`` and the intercept
   ``shard_fixed``.
3. **composed** — the batched step with the scenario axis sharded
   (``batch_shards = 2``) and TWO scenarios per shard isolates
   ``batch_fixed`` — the incremental fixed cost per additional local
   scenario vmapped through a tile — as the residual over the sharded
   prediction.  Skipped (constant left at its default, and flagged in
   the metadata) when the host has fewer than 4 devices.

``--emit FILE`` writes a JSON constants file round-trippable through
:func:`repro.core.engine.load_cost_constants`; point ``REPRO_COST_MODEL``
at it (or call ``load_cost_constants``) to make every subsequent
``compile_plan`` use the measured values instead of the guesses.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import sys
import time

sys.path.insert(0, "src")

from repro.core import engine                              # noqa: E402

engine.expose_host_devices()   # before anything imports jax

import jax                                                 # noqa: E402
import jax.numpy as jnp                                    # noqa: E402
import numpy as np                                         # noqa: E402

from repro.core.config import SimConfig                    # noqa: E402
from repro.core.sharded import ShardedSim                  # noqa: E402
from repro.core.sim import _run_jit                        # noqa: E402
from repro.core.state import init_state                    # noqa: E402
from repro.core.trace import random_trace                  # noqa: E402
from jax.sharding import Mesh                              # noqa: E402


def _cfg(rows: int) -> SimConfig:
    # home-sharded directory everywhere so dense and sharded time the
    # same semantics; a huge refs count keeps the sim busy past the
    # timing window, and livelock_window=0 disables the early-abort
    # monitor (we are timing throughput, not finishing runs)
    return SimConfig(rows=rows, cols=rows, centralized_directory=False,
                     dir_layout="home", livelock_window=0)


def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def time_dense(rows: int, refs: int, cycles: int, chunk: int,
               reps: int) -> float:
    """Seconds per simulated cycle of the dense vmapped driver."""
    cfg = _cfg(rows)
    s = init_state(cfg, random_trace(cfg, refs, seed=0))
    cap = jnp.asarray(cycles, jnp.int32)

    def go():
        out, _ = _run_jit(s, cfg, cap, chunk)
        out.cycle.block_until_ready()
        assert int(out.cycle) == cycles, "workload finished inside the " \
            "timing window; raise --refs"

    go()                       # compile + warm
    return _best_of(go, reps) / cycles


def time_step(sim: ShardedSim, cycles: int, reps: int) -> float:
    """Seconds per simulated cycle of a (possibly composed) sharded step."""
    step = sim.build_step(cycles)

    def go():
        out = step(sim.state, *sim.geo)
        out.cycle.block_until_ready()
        return out

    out = go()                 # compile + warm (state NOT advanced: the
    # timed calls reuse sim.state).  Like time_dense: a sim that finishes
    # inside the window would freeze into a no-op and poison the fit.
    assert int(np.min(np.asarray(out.cycle))) == cycles, \
        "workload finished inside the timing window; raise --refs"
    return _best_of(go, reps) / cycles


def calibrate(args) -> dict:
    ndev = len(jax.devices())
    n1, n2 = args.rows_small ** 2, args.rows_large ** 2
    nt = max(d for d in range(1, min(ndev, 4) + 1)
             if args.rows_small % d == 0 and args.rows_large % d == 0
             and d <= ndev)
    meas = {"devices": ndev, "spatial_tiles": nt,
            "cycles": args.cycles, "reps": args.reps}

    t_d1 = time_dense(args.rows_small, args.refs, args.cycles,
                      args.chunk, args.reps)
    t_d2 = time_dense(args.rows_large, args.refs, args.cycles,
                      args.chunk, args.reps)
    unit = (t_d1 / n1 + t_d2 / n2) / 2          # s per node-cycle
    meas.update(dense_s_per_cycle={str(n1): t_d1, str(n2): t_d2},
                unit_s_per_node_cycle=unit)

    defaults = engine.CostConstants()
    if nt <= 1:
        # single device: no collective to measure — keep the defaults
        meas["note"] = "single device; sharded/composed not measurable"
        return {"constants": defaults, "meta": meas}

    def sharded_sim(rows):
        cfg = _cfg(rows)
        tr = random_trace(cfg, args.refs, seed=0)
        mesh = Mesh(np.asarray(jax.devices()[:nt]).reshape(1, nt),
                    ("data", "model"))
        return ShardedSim(cfg, tr, mesh)

    y1 = time_step(sharded_sim(args.rows_small), args.cycles, args.reps)
    y2 = time_step(sharded_sim(args.rows_large), args.cycles, args.reps)
    meas["sharded_s_per_cycle"] = {str(n1): y1, str(n2): y2}

    halo = (y2 - y1) / ((n2 - n1) / nt) / unit
    halo = max(halo, 1.0)      # a tile step can't beat the dense per-node cost
    fixed = max(y1 / unit - n1 / nt * halo, 0.0)

    batch_fixed = defaults.batch_fixed
    if ndev >= 2 * nt:
        # 4 scenarios over batch_shards=2 -> local batch of 2: the
        # residual over the sharded prediction is (local_b - 1) = 1
        # batch_fixed units
        cfg = _cfg(args.rows_large)
        tr = np.stack([random_trace(cfg, args.refs, seed=s)
                       for s in range(4)])
        mesh = Mesh(np.asarray(jax.devices()[:2 * nt]).reshape(2, 1, nt),
                    ("scenario", "data", "model"))
        sim = ShardedSim(cfg, tr, mesh, batch_axes=("scenario",))
        y3 = time_step(sim, args.cycles, args.reps)
        meas["composed_s_per_cycle_localb2"] = {str(n2): y3}
        batch_fixed = max(y3 / unit - 2 * n2 / nt * halo - fixed, 0.0)
    else:
        meas["note"] = (f"{ndev} device(s) < {2 * nt}: batch_fixed not "
                        "measurable, default kept")

    return {"constants": engine.CostConstants(
        halo_overhead=round(halo, 3), shard_fixed=round(fixed, 1),
        batch_fixed=round(batch_fixed, 1)), "meta": meas}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows-small", type=int, default=16,
                    help="smaller calibration mesh edge (rows == cols)")
    ap.add_argument("--rows-large", type=int, default=32,
                    help="larger calibration mesh edge")
    ap.add_argument("--refs", type=int, default=100_000,
                    help="refs per core; must outlast the timing window")
    ap.add_argument("--cycles", type=int, default=256,
                    help="simulated cycles per timed program call")
    ap.add_argument("--chunk", type=int, default=64,
                    help="dense-driver chunk (cycles per termination check)")
    ap.add_argument("--reps", type=int, default=3,
                    help="timing repetitions (best-of)")
    ap.add_argument("--emit", default=None, metavar="FILE",
                    help="write the constants file the planner loads via "
                         "REPRO_COST_MODEL / engine.load_cost_constants")
    args = ap.parse_args()

    res = calibrate(args)
    c = res["constants"]
    meta = {"platform": platform.platform(),
            "jax_backend": jax.default_backend(),
            "argv": sys.argv[1:], **res["meta"]}
    print(json.dumps({**dataclasses.asdict(c), "meta": meta}, indent=1))
    if args.emit:
        engine.save_cost_constants(args.emit, c, meta=meta)
        print(f"wrote {args.emit}; planner picks it up via "
              f"REPRO_COST_MODEL={args.emit}", file=sys.stderr)


if __name__ == "__main__":
    main()
