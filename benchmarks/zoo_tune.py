"""Tune the ejection-guarantee thresholds across a scenario zoo.

    PYTHONPATH=src python benchmarks/zoo_tune.py \
        --out benchmarks/zoo_thresholds.json

The pending-completion queue's ejection guarantee (docs/architecture.md)
has two thresholds: ``eject_age_threshold`` (a *traced* per-scenario
knob — varying it never splits a compile bucket) and ``req_timeout``
(a compiled constant — each value is its own bucket).  They were tuned
on the single ROADMAP wedge family; this harness closes that residual by
sweeping both across any set of zoo families (:mod:`repro.core.zoo`).

Sweep structure (this is why the whole thing is cheap):

* for each ``req_timeout`` value, ONE plan holds every (scenario x
  eject-age) variant — the age rides as ``SimState.knob_ej_age``, so a
  bucket of B scenarios x A ages compiles ONCE and runs as a batch of
  B*A lanes through :func:`repro.core.sweep.run_sweep`;
* the planner splits buckets only on mesh shape (and ``req_timeout``),
  so a full grid over Z zoo scenarios costs ``len(timeouts) x
  n_mesh_shapes`` compiles, not ``Z x A x T``.

Scoring: a config ``(req_timeout, eject_age_threshold)`` is *safe* when
every scenario finishes (no livelock abort, no cycle-cap overrun).  Among
safe configs the score is the mean per-scenario completion-cycle count
normalized by that scenario's best observed cycles (lower = faster).
The emitted JSON holds the full table, the current defaults' row, and a
``recommendation`` — with a stability bias: the defaults are kept unless
a challenger is more than ``--flip-margin`` (default 1%) faster.

``--smoke`` runs a tiny slice (patterns-tiny, one timeout, two ages),
self-checks the emitted JSON shape, and exits — the CI ``zoo-smoke``
job's second half.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import platform
import sys
import time

sys.path.insert(0, "src")

from repro.core import engine                              # noqa: E402

engine.expose_host_devices()   # before anything imports jax

from repro.core.config import SimConfig                    # noqa: E402
from repro.core.engine import Scenario                     # noqa: E402
from repro.core.zoo import expand_zoo                      # noqa: E402

DEFAULT_ZOOS = ("patterns-small", "hotspot-stress", "patterns-rates",
                "wedge")
DEFAULTS = {"eject_age_threshold": SimConfig.eject_age_threshold,
            "req_timeout": SimConfig.req_timeout}


def run_grid(base_scenarios, ej_ages, timeouts, max_cycles, chunk):
    """Run every (scenario, age, timeout) variant; returns
    ``{(timeout, age): [stats per base scenario]}``.

    One :func:`repro.core.engine.plan_and_run` call per timeout carries
    all age variants as traced knobs (ONE compile per mesh shape)."""
    table = {}
    for tmo in timeouts:
        variants = [
            Scenario(cfg=dataclasses.replace(sc.cfg, req_timeout=tmo,
                                             eject_age_threshold=age),
                     app=sc.app, seed=sc.seed,
                     refs_per_core=sc.refs_per_core)
            for age in ej_ages for sc in base_scenarios]
        t0 = time.time()
        res = engine.plan_and_run(variants, max_cycles=max_cycles,
                                  chunk=chunk)
        print(f"req_timeout={tmo}: {len(variants)} variant runs in "
              f"{time.time() - t0:.1f}s", file=sys.stderr)
        for ai, age in enumerate(ej_ages):
            lo = ai * len(base_scenarios)
            table[(tmo, age)] = res[lo:lo + len(base_scenarios)]
    return table


def score(table, base_scenarios):
    """Per-config rows + recommendation inputs from the raw grid."""
    nsc = len(base_scenarios)
    # best observed completion cycles per base scenario (finished runs)
    best = [None] * nsc
    for res in table.values():
        for i, st in enumerate(res):
            if st.get("finished"):
                c = st["cycles"]
                best[i] = c if best[i] is None else min(best[i], c)
    rows = []
    for (tmo, age), res in table.items():
        unfinished = [i for i, st in enumerate(res)
                      if not st.get("finished")]
        aborted = [i for i, st in enumerate(res) if "aborted" in st]
        norms = [st["cycles"] / best[i] for i, st in enumerate(res)
                 if st.get("finished") and best[i]]
        rows.append({
            "req_timeout": tmo,
            "eject_age_threshold": age,
            "finished": nsc - len(unfinished),
            "unfinished": len(unfinished),
            "aborted": len(aborted),
            "unfinished_scenarios": [
                f"{base_scenarios[i].cfg.rows}x{base_scenarios[i].cfg.cols}"
                f":{base_scenarios[i].app}:{base_scenarios[i].seed}"
                for i in unfinished],
            "mean_norm_cycles": (round(sum(norms) / len(norms), 4)
                                 if norms else None),
            "total_drops": sum(st.get("send_drop", 0) for st in res),
        })
    rows.sort(key=lambda r: (r["req_timeout"], r["eject_age_threshold"]))
    return rows


def recommend(rows, flip_margin):
    """Pick the recommended config: safest first, then fastest, with a
    stability bias of ``flip_margin`` toward the current defaults."""
    safe = [r for r in rows if r["unfinished"] == 0
            and r["mean_norm_cycles"] is not None]
    if not safe:
        return None, False, "no config finished every scenario"
    best = min(safe, key=lambda r: r["mean_norm_cycles"])
    in_grid = [r for r in rows
               if r["req_timeout"] == DEFAULTS["req_timeout"]
               and r["eject_age_threshold"]
               == DEFAULTS["eject_age_threshold"]]
    if not in_grid:
        # the defaults were never measured: recommend the best swept
        # config but claim no authority to flip
        return best, False, (
            f"current defaults {DEFAULTS} were not in the swept grid; "
            "best swept config reported, no basis to flip")
    cur = [r for r in in_grid if r in safe]
    if cur:
        gain = cur[0]["mean_norm_cycles"] - best["mean_norm_cycles"]
        if gain <= flip_margin:
            return cur[0], False, (
                f"defaults are safe and within {flip_margin:.0%} of the "
                f"best config (gain {gain:.4f}); keeping them")
        return best, True, (
            f"best config beats the safe defaults by {gain:.4f} "
            f"normalized cycles (> {flip_margin:.0%} margin)")
    return best, True, "current defaults left scenarios unfinished"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--zoo", default=",".join(DEFAULT_ZOOS),
                    help="comma list of zoo family specs to tune over")
    ap.add_argument("--ej-ages", default="0,2,4,8,16",
                    help="comma list of eject_age_threshold values")
    ap.add_argument("--timeouts", default="64,256,1024",
                    help="comma list of req_timeout values")
    ap.add_argument("--max-cycles", type=int, default=100_000)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--flip-margin", type=float, default=0.01,
                    help="minimum normalized-cycles gain before the "
                         "recommendation moves off the current defaults")
    ap.add_argument("--out", default=None,
                    help="write the recommendation JSON here")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny slice + self-check of the emitted JSON "
                         "(CI zoo-smoke)")
    args = ap.parse_args()

    if args.smoke:
        zoos = ["patterns-tiny:refs=8,seeds=0"]
        ej_ages, timeouts = [0, 8], [256]
        args.max_cycles = min(args.max_cycles, 50_000)
    else:
        zoos = [z for z in args.zoo.split(",") if z.strip()]
        ej_ages = [int(x) for x in args.ej_ages.split(",")]
        timeouts = [int(x) for x in args.timeouts.split(",")]

    base_scenarios = []
    for z in zoos:
        base_scenarios.extend(expand_zoo(z))
    print(f"zoo: {zoos} -> {len(base_scenarios)} scenarios x "
          f"{len(ej_ages)} ages x {len(timeouts)} timeouts",
          file=sys.stderr)

    table = run_grid(base_scenarios, ej_ages, timeouts,
                     args.max_cycles, args.chunk)
    rows = score(table, base_scenarios)
    rec, flip, why = recommend(rows, args.flip_margin)

    import jax
    payload = {
        "meta": {
            "zoos": zoos,
            "n_scenarios": len(base_scenarios),
            "ej_ages": ej_ages,
            "timeouts": timeouts,
            "max_cycles": args.max_cycles,
            "host": platform.node(),
            "platform": jax.devices()[0].platform,
            "devices": len(jax.devices()),
        },
        "defaults": DEFAULTS,
        "table": rows,
        "recommendation": (None if rec is None else {
            "req_timeout": rec["req_timeout"],
            "eject_age_threshold": rec["eject_age_threshold"],
            "mean_norm_cycles": rec["mean_norm_cycles"],
        }),
        "flip_defaults": flip,
        "rationale": why,
    }
    text = json.dumps(payload, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    print(text)

    if args.smoke:
        # self-check: the harness must emit a well-formed recommendation
        assert payload["table"], "empty table"
        for r in payload["table"]:
            for k in ("req_timeout", "eject_age_threshold", "finished",
                      "unfinished", "aborted", "mean_norm_cycles"):
                assert k in r, (k, r)
        assert payload["recommendation"] is not None, payload["rationale"]
        assert isinstance(payload["flip_defaults"], bool)
        print("SMOKE OK", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
