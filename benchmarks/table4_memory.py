"""Paper Table 4: cache configuration vs maximum simulatable core count.

    PYTHONPATH=src python benchmarks/table4_memory.py [--out f]

The paper's limit is GPU global memory (43k cores on a GTX 690, dropping
to 30k with migration metadata, 2k with big caches).  Here: exact
simulator-state bytes per simulated core for each cache configuration —
under both state-dtype policies (``wide`` = all-int32 storage, ``packed``
= narrowest dtype the config bounds allow) — and the implied maximum
cores per 16 GiB TPU v5e chip and per 512-chip job.

Three measurement layers, cross-checked against each other:

* per paper row: ``jax.eval_shape`` over ``init_state`` (dtype-aware),
  with migration metadata elided for the paper's "without" row;
* a representative sweep config: the analytic
  :func:`repro.core.state.state_bytes` estimator the planner uses;
* the same config *materialized*, measured as actual live device-buffer
  bytes via ``jax.live_arrays()`` — if the analytic number ever drifts
  from what the runtime really allocates, this benchmark fails.

``bytes_per_core`` is a pure function of the state layout, so the
metrics gate at zero slack: any state-struct growth shows up here first.
"""
from __future__ import annotations

import dataclasses
import sys

sys.path.insert(0, "src")

import jax                                                      # noqa: E402
import numpy as np                                              # noqa: E402

from repro.bench import BenchReport, Benchmark, bench_main      # noqa: E402
from repro.core import SimConfig                                # noqa: E402
from repro.core.config import CacheConfig                       # noqa: E402
from repro.core.state import init_state, state_bytes            # noqa: E402

CONFIGS = [
    ("L1 128x4, L2 512x8 (paper row 1)", CacheConfig(128, 4, 32, 512, 8, 64), True),
    ("L1 128x4, L2 128x4 (paper row 2)", CacheConfig(128, 4, 32, 128, 4, 64), True),
    ("L1 32x2,  L2 32x2 + migration", CacheConfig(32, 2, 32, 32, 2, 64), True),
    ("L1 32x2,  L2 32x2 no migration", CacheConfig(32, 2, 32, 32, 2, 64), False),
]

HBM = 16 * 2**30

#: migration metadata leaves elided for the paper's "without" row
_MIG_LEAVES = ("l2_last", "l2_streak", "fwd_tag", "fwd_dst", "fwd_ptr")

#: the representative config for the packed-vs-wide headline numbers:
#: a 16x16 sweep mesh whose bounds let every narrowable field narrow
#: (node ids and tags fit int16; at the paper-scale 208x208 mesh the id
#: fields are forced back to int32 and the ratio lands higher)
REP = dict(rows=16, cols=16, addr_bits=14, max_cycles=8192,
           dir_layout="home", centralized_directory=False)
REP_REFS = 200


def bytes_per_core(cache: CacheConfig, migration: bool,
                   policy: str = "wide", refs: int = 200) -> int:
    cfg = SimConfig(rows=4, cols=4, cache=cache, addr_bits=16,
                    migration_enabled=migration,
                    centralized_directory=False, dir_layout="home",
                    state_dtype_policy=policy)
    tr = np.zeros((cfg.num_nodes, refs), np.int32)
    st = jax.eval_shape(lambda t: init_state(cfg, t), tr)
    total = 0
    for name, leaf in st._asdict().items():
        n = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        if not migration and name in _MIG_LEAVES:
            continue   # migration metadata elided (paper's "without")
        total += n
    return total // cfg.num_nodes


def live_bytes_per_node(cfg: SimConfig, refs: int = REP_REFS) -> int:
    """Actually allocate the state and count the new live device buffers
    (``jax.live_arrays``) — the runtime's answer, not the estimator's."""
    tr = np.zeros((cfg.num_nodes, refs), np.int32)
    before = {id(a) for a in jax.live_arrays()}
    st = jax.block_until_ready(init_state(cfg, tr))
    live = sum(a.nbytes for a in jax.live_arrays() if id(a) not in before)
    del st
    return live // cfg.num_nodes


def add_args(ap) -> None:
    pass   # the table is parameter-free (configs are the paper's rows)


def run_bench(args) -> BenchReport:
    """Contract entry: state bytes/core per cache config (both dtype
    policies) + implied caps, plus analytic-vs-live cross-check at the
    representative config."""
    rows = []
    print(f"{'config':38s} {'wide':>8s} {'packed':>8s} "
          f"{'max cores/chip':>15s} {'max cores/512':>14s}")
    for name, cache, mig in CONFIGS:
        b = bytes_per_core(cache, mig, "wide")
        bp = bytes_per_core(cache, mig, "packed")
        per_chip = HBM // bp
        rows.append({"config": name, "bytes_per_core": b,
                     "bytes_per_core_packed": bp,
                     "max_per_chip": per_chip,
                     "max_512": per_chip * 512})
        print(f"{name:38s} {b:>8d} {bp:>8d} {per_chip:>15,d} "
              f"{per_chip*512:>14,d}")
    print("\npaper (GTX 690, 2 GiB/GPU): 2,000 / 10,000 / 30,000 / 43,000")

    rep_w = SimConfig(state_dtype_policy="wide", **REP)
    rep_p = SimConfig(state_dtype_policy="packed", **REP)
    n = rep_w.num_nodes
    est_w = state_bytes(rep_w, trace_len=REP_REFS) // n
    est_p = state_bytes(rep_p, trace_len=REP_REFS) // n
    live_w = live_bytes_per_node(rep_w)
    live_p = live_bytes_per_node(rep_p)
    ratio = est_p / est_w
    print(f"\nrepresentative 16x16 sweep config, bytes/node:")
    print(f"  wide   analytic {est_w:>6d}  live {live_w:>6d}")
    print(f"  packed analytic {est_p:>6d}  live {live_p:>6d}"
          f"   ratio {ratio:.3f}")
    if (est_w, est_p) != (live_w, live_p):
        raise AssertionError(
            f"state_bytes estimator drifted from live buffers: "
            f"analytic (wide {est_w}, packed {est_p}) vs "
            f"live (wide {live_w}, packed {live_p})")

    rep = BenchReport("table4", raw={
        "rows": rows,
        "representative": {"config": REP, "refs": REP_REFS,
                           "wide": est_w, "packed": est_p,
                           "live_wide": live_w, "live_packed": live_p,
                           "ratio": ratio}})
    for i, row in enumerate(rows):
        rep.add(f"table4.row{i}.bytes_per_core", row["bytes_per_core"],
                unit="B/core", direction="lower",
                tags={"config": row["config"], "policy": "wide"})
        rep.add(f"table4.row{i}.bytes_per_core_packed",
                row["bytes_per_core_packed"],
                unit="B/core", direction="lower",
                tags={"config": row["config"], "policy": "packed"})
        rep.add(f"table4.row{i}.max_per_chip", row["max_per_chip"],
                unit="cores", direction="higher", gate=False,
                tags={"config": row["config"]})
    rep.add("table4.state_bytes_per_node.wide", est_w,
            unit="B/node", direction="lower", tags={"config": "rep-16x16"})
    rep.add("table4.state_bytes_per_node.packed", est_p,
            unit="B/node", direction="lower", tags={"config": "rep-16x16"})
    rep.add("table4.live_bytes_per_node.wide", live_w,
            unit="B/node", direction="lower", tags={"config": "rep-16x16"})
    rep.add("table4.live_bytes_per_node.packed", live_p,
            unit="B/node", direction="lower", tags={"config": "rep-16x16"})
    rep.add("table4.packed_wide_ratio", round(ratio, 4),
            unit="x", direction="lower", tags={"config": "rep-16x16"})
    return rep


BENCH = Benchmark(
    area="table4",
    title="Paper Table 4: simulator-state bytes/core vs max simulated cores",
    add_args=add_args,
    run=run_bench,
    gated=True,
)


def main(argv=None) -> BenchReport:
    return bench_main(BENCH, argv)


if __name__ == "__main__":
    main()
