"""Paper Table 4: cache configuration vs maximum simulatable core count.

    PYTHONPATH=src python benchmarks/table4_memory.py [--out f]

The paper's limit is GPU global memory (43k cores on a GTX 690, dropping
to 30k with migration metadata, 2k with big caches).  Here: exact
simulator-state bytes per simulated core for each cache configuration, and
the implied maximum cores per 16 GiB TPU v5e chip and per 512-chip job.
``bytes_per_core`` is a pure function of the state layout, so the metric
gates at zero slack: any state-struct growth shows up here first.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "src")

import jax                                                      # noqa: E402
import numpy as np                                              # noqa: E402

from repro.bench import BenchReport, Benchmark, bench_main      # noqa: E402
from repro.core import SimConfig                                # noqa: E402
from repro.core.config import CacheConfig                       # noqa: E402
from repro.core.state import init_state                         # noqa: E402

CONFIGS = [
    ("L1 128x4, L2 512x8 (paper row 1)", CacheConfig(128, 4, 32, 512, 8, 64), True),
    ("L1 128x4, L2 128x4 (paper row 2)", CacheConfig(128, 4, 32, 128, 4, 64), True),
    ("L1 32x2,  L2 32x2 + migration", CacheConfig(32, 2, 32, 32, 2, 64), True),
    ("L1 32x2,  L2 32x2 no migration", CacheConfig(32, 2, 32, 32, 2, 64), False),
]

HBM = 16 * 2**30


def bytes_per_core(cache: CacheConfig, migration: bool, refs: int = 200) -> int:
    cfg = SimConfig(rows=4, cols=4, cache=cache, addr_bits=16,
                    migration_enabled=migration,
                    centralized_directory=False, dir_layout="home")
    tr = np.zeros((cfg.num_nodes, refs), np.int32)
    st = jax.eval_shape(lambda t: init_state(cfg, t), tr)
    total = 0
    for name, leaf in st._asdict().items():
        n = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        if not migration and name in ("l2_last", "l2_streak", "fwd_tag",
                                      "fwd_dst", "fwd_ptr"):
            continue   # migration metadata elided (paper's "without")
        total += n
    return total // cfg.num_nodes


def add_args(ap) -> None:
    pass   # the table is parameter-free (configs are the paper's rows)


def run_bench(args) -> BenchReport:
    """Contract entry: state bytes/core per cache config + implied caps."""
    rows = []
    print(f"{'config':38s} {'B/core':>8s} {'max cores/chip':>15s} "
          f"{'max cores/512':>14s}")
    for name, cache, mig in CONFIGS:
        b = bytes_per_core(cache, mig)
        per_chip = HBM // b
        rows.append({"config": name, "bytes_per_core": b,
                     "max_per_chip": per_chip,
                     "max_512": per_chip * 512})
        print(f"{name:38s} {b:>8d} {per_chip:>15,d} {per_chip*512:>14,d}")
    print("\npaper (GTX 690, 2 GiB/GPU): 2,000 / 10,000 / 30,000 / 43,000")
    rep = BenchReport("table4", raw={"rows": rows})
    for i, row in enumerate(rows):
        rep.add(f"table4.row{i}.bytes_per_core", row["bytes_per_core"],
                unit="B/core", direction="lower",
                tags={"config": row["config"]})
        rep.add(f"table4.row{i}.max_per_chip", row["max_per_chip"],
                unit="cores", direction="higher", gate=False,
                tags={"config": row["config"]})
    return rep


BENCH = Benchmark(
    area="table4",
    title="Paper Table 4: simulator-state bytes/core vs max simulated cores",
    add_args=add_args,
    run=run_bench,
    gated=False,
)


def main(argv=None) -> BenchReport:
    return bench_main(BENCH, argv)


if __name__ == "__main__":
    main()
