"""Serve a small LM with batched requests (continuous batching).

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import registry
from repro.models import api
from repro.serve.server import Request, Server


def main() -> None:
    cfg = registry.reduced("tinyllama-1.1b")
    params = api.init_params(cfg, jax.random.key(0))
    server = Server(cfg, params, slots=4, cache_len=128, temperature=0.0)

    rng = np.random.default_rng(0)
    for rid in range(10):
        prompt = rng.integers(0, cfg.vocab, size=rng.integers(2, 8)
                              ).astype(np.int32)
        server.submit(Request(rid=rid, prompt=prompt, max_new=12))

    finished = server.run_until_drained()
    assert len(finished) == 10, len(finished)
    for req in finished:
        print(f"req {req.rid}: prompt {req.prompt.tolist()} -> {req.out}")
    print(f"served {len(finished)} requests with continuous batching")


if __name__ == "__main__":
    main()
