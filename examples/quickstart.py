"""Quickstart: simulate a 16x16-core bufferless LCMP on one device.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import SimConfig, run
from repro.core.ref_serial import SerialSim
from repro.core.trace import app_trace


def main() -> None:
    cfg = SimConfig(rows=8, cols=8, addr_bits=18, migrate_threshold=2)
    trace = app_trace(cfg, "matmul", refs_per_core=60, seed=1)

    print("== vectorized JAX simulator (the paper's GPU version, TPU-form) ==")
    stats = run(cfg, trace, chunk=8)
    for k in ("cycles", "req_made", "reply_sent", "trap", "redirection",
              "migrations", "dir_search", "l1_hits", "l1_misses",
              "deflections", "injected"):
        print(f"  {k:14s} {stats[k]}")

    print("== serial golden model (the paper's C++ version) ==")
    ref = SerialSim(cfg, trace).run()
    same = all(ref[k] == stats[k] for k in ref)
    print(f"  identical statistics: {same}")
    assert same


if __name__ == "__main__":
    main()
