"""Scale demo: simulate a large LCMP, sharded across all local devices.

The paper's headline is 43,000 simulated cores on one GTX 690; the sharded
simulator tiles the router grid over a device mesh (halo-exchange
collectives), so the same binary scales from a laptop to a 512-chip pod.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/simulate_large_noc.py --rows 64 --cols 64
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.core.config import SimConfig
from repro.core.sharded import ShardedSim
from repro.core.trace import app_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=64)
    ap.add_argument("--cols", type=int, default=64)
    ap.add_argument("--refs", type=int, default=40)
    ap.add_argument("--app", default="mgrid")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    rt = 1
    for cand in range(int(n_dev ** 0.5), 0, -1):
        if n_dev % cand == 0 and args.rows % cand == 0 \
                and args.cols % (n_dev // cand) == 0:
            rt = cand
            break
    mesh = jax.make_mesh((rt, n_dev // rt), ("data", "model"))
    print(f"simulating {args.rows}x{args.cols} = {args.rows*args.cols} cores "
          f"over {n_dev} devices (tiles {rt}x{n_dev//rt})")

    cfg = SimConfig(rows=args.rows, cols=args.cols, addr_bits=20,
                    centralized_directory=False, dir_layout="home")
    trace = app_trace(cfg, args.app, args.refs, seed=1)
    sim = ShardedSim(cfg, trace, mesh)
    t0 = time.time()
    stats = sim.run(chunk=128)
    dt = time.time() - t0
    print(f"finished={stats['finished']} cycles={stats['cycles']} "
          f"wall={dt:.1f}s")
    for k in ("req_made", "trap", "redirection", "migrations",
              "deflections", "injected"):
        print(f"  {k:12s} {stats[k]}")


if __name__ == "__main__":
    main()
