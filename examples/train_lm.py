"""End-to-end driver: train a ~100M-param llama-style LM for a few hundred
steps with checkpoint/restart, on CPU or any accelerator.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.models.config import ModelConfig
from repro.train.data import DataConfig
from repro.train.loop import LoopConfig, Trainer
from repro.train.optim import OptConfig


def small_lm() -> ModelConfig:
    """~100M params (tinyllama family, narrowed)."""
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=8, d_model=640,
        n_heads=10, n_kv_heads=2, d_ff=1792, vocab=32000, max_seq=1024,
        remat=False)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="checkpoints/lm-100m")
    args = ap.parse_args()

    cfg = small_lm()
    n = cfg.n_params()
    print(f"model: {cfg.name} ({n/1e6:.0f}M params)")

    trainer = Trainer(
        cfg,
        OptConfig(lr=6e-4, warmup=30, total_steps=args.steps),
        DataConfig(batch=args.batch, seq=args.seq, seed=3),
        LoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=100,
                   log_every=20),
    )
    out = trainer.run()
    first = trainer.metrics_log[0]["loss"] if trainer.metrics_log else None
    print(f"first loss {first:.3f} -> final loss {out['final_loss']:.3f}")
    assert out["final_loss"] < (first or 1e9), "training did not reduce loss"


if __name__ == "__main__":
    main()
