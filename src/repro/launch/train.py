"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 50 --batch 8 --seq 256

Full-size configs target the production mesh (run under real TPU slices or
with XLA_FLAGS=--xla_force_host_platform_device_count=N for dry exercises);
``--reduced`` runs the same code path single-device.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import registry
from repro.train.data import DataConfig
from repro.train.loop import LoopConfig, Trainer
from repro.train.optim import OptConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    args = ap.parse_args()

    cfg = registry.reduced(args.arch) if args.reduced else registry.get(args.arch)
    mesh = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    trainer = Trainer(
        cfg,
        OptConfig(lr=args.lr, warmup=min(20, args.steps // 5 + 1),
                  total_steps=args.steps),
        DataConfig(batch=args.batch, seq=args.seq),
        LoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                   ckpt_every=args.ckpt_every, resume=not args.no_resume),
        mesh=mesh,
    )
    out = trainer.run()
    print(f"[train] done: {out}")


if __name__ == "__main__":
    main()
