"""NoC-simulation launcher (the paper's workload).

    PYTHONPATH=src python -m repro.launch.simulate --rows 16 --cols 16 \
        --app matmul --refs 100
Batched multi-scenario sweep (one compiled program for all scenarios):
    ... --sweep --apps matmul,equake,mgrid --seeds 0,1
Multi-device:
    ... --sharded   (tiles the simulated mesh over jax.devices())
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.core.config import SimConfig
from repro.core.trace import app_trace, random_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=16)
    ap.add_argument("--cols", type=int, default=16)
    ap.add_argument("--app", default="matmul")
    ap.add_argument("--refs", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--centralized", action="store_true",
                    help="paper-default centralized directory (hot spot!)")
    ap.add_argument("--no-migration", action="store_true")
    ap.add_argument("--serial", action="store_true",
                    help="run the golden-model serial simulator instead")
    ap.add_argument("--sharded", action="store_true")
    ap.add_argument("--sweep", action="store_true",
                    help="batched sweep: run apps x seeds scenarios in one "
                         "compiled program (repro.core.sweep)")
    ap.add_argument("--apps", default=None,
                    help="comma list of apps for --sweep (default: --app)")
    ap.add_argument("--seeds", default=None,
                    help="comma list of seeds for --sweep (default: --seed)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="simulated cycles per device-loop termination check")
    ap.add_argument("--max-cycles", type=int, default=200_000)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    cfg = SimConfig(rows=args.rows, cols=args.cols,
                    centralized_directory=args.centralized,
                    dir_layout="home" if args.sharded else "flat",
                    migration_enabled=not args.no_migration,
                    max_cycles=args.max_cycles)

    if args.sweep and (args.sharded or args.serial):
        ap.error("--sweep cannot be combined with --sharded or --serial "
                 "(the sweep engine batches the vectorized simulator; "
                 "spatial sharding of sweeps is a ROADMAP item)")

    if args.sweep:
        # expose the cores as XLA host devices so the sweep shards its
        # scenario axis across them (must precede the first jax import)
        if "jax" not in sys.modules \
                and "--xla_force_host_platform_device_count" \
                not in os.environ.get("XLA_FLAGS", ""):
            os.environ["XLA_FLAGS"] = (
                os.environ.get("XLA_FLAGS", "")
                + f" --xla_force_host_platform_device_count={os.cpu_count()}")
        from repro.core.sweep import SweepSpec, run_sweep
        apps = (args.apps or args.app).split(",")
        seeds = [int(x) for x in (args.seeds or str(args.seed)).split(",")]
        spec = SweepSpec.cross(cfg, apps, seeds, args.refs)
        t0 = time.time()
        per_scenario = run_sweep(spec, chunk=args.chunk)
        dt = time.time() - t0
        payload = {
            "scenarios": [
                {"app": sc.app, "seed": sc.seed, **st}
                for sc, st in zip(spec.scenarios, per_scenario)],
            "n_scenarios": spec.size,
            "nodes": cfg.num_nodes,
            "wall_s": round(dt, 2),
            "scenarios_per_sec": round(spec.size / dt, 3),
        }
        print(json.dumps(payload, indent=1))
        if args.json:
            with open(args.json, "w") as f:
                json.dump(payload, f)
        return

    tr = (random_trace(cfg, args.refs, args.seed) if args.app == "random"
          else app_trace(cfg, args.app, args.refs, args.seed))

    t0 = time.time()
    if args.serial:
        from repro.core.ref_serial import SerialSim
        stats = SerialSim(cfg, tr).run()
    elif args.sharded:
        import jax
        from repro.core.sharded import ShardedSim
        n = len(jax.devices())
        rows_tiles = 1
        for cand in range(int(n ** 0.5), 0, -1):
            if n % cand == 0 and args.rows % cand == 0 \
                    and args.cols % (n // cand) == 0:
                rows_tiles = cand
                break
        mesh = jax.make_mesh((rows_tiles, n // rows_tiles),
                             ("data", "model"))
        stats = ShardedSim(cfg, tr, mesh).run()
    else:
        from repro.core.sim import run
        stats = run(cfg, tr, chunk=args.chunk)
    dt = time.time() - t0

    stats["wall_s"] = round(dt, 2)
    stats["nodes"] = cfg.num_nodes
    print(json.dumps(stats, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(stats, f)


if __name__ == "__main__":
    main()
