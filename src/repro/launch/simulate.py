"""NoC-simulation launcher (the paper's workload).

    PYTHONPATH=src python -m repro.launch.simulate --rows 16 --cols 16 \
        --app matmul --refs 100

Every mode (except ``--serial``) routes through the execution-plan layer
(:mod:`repro.core.engine`): scenarios are bucketed by structural config,
each bucket compiles once, and a cost model picks the batched-sweep,
spatially-sharded or composed backend per bucket (see
``docs/architecture.md``).

Batched multi-scenario sweep (one compiled program for all scenarios):
    ... --sweep --apps matmul,equake,mgrid --seeds 0,1
Force a backend for any planner mode (each degrades to ``sweep`` with an
explanatory note when structurally impossible on this host):
    ... --backend sharded
    ... --sweep --apps matmul --seeds 0,1,2,3 --backend composed
Heterogeneous plan — mixed mesh shapes/apps/knobs from a manifest (a JSON
file, inline JSON, or the compact ROWSxCOLS[:APP][:SEED[:REFS]] grammar;
APP is any workload-registry source spec):
    ... --plan manifest.json
    ... --plan '8x8:matmul:0:50;16x16:equake:1:50'
    ... --plan '8x8:hotspot:frac=0.8,hot=2:0:50'
Scenario zoo — run a registered family (repro.core.zoo) end to end:
    ... --zoo patterns-small
    ... --zoo patterns-tiny:refs=8,seeds=0
    ... --zoo list

``docs/cli.md`` is generated from this parser by
``scripts/gen_cli_docs.py`` (CI fails on drift) — keep flag help strings
self-contained.  The ``--app`` help and error text are generated from
the traffic-generator registry, so new generators appear automatically.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import warnings

from repro.core.workloads import source_summary

BACKENDS = ("auto", "sweep", "sharded", "composed")


def build_parser() -> argparse.ArgumentParser:
    """The launcher's argparse tree (also the source of ``docs/cli.md``)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.simulate",
        description="Bufferless-NoC simulator launcher: solo runs, batched "
                    "sweeps and heterogeneous execution plans, all through "
                    "the repro.core.engine planner.")
    ap.add_argument("--rows", type=int, default=16,
                    help="simulated mesh rows")
    ap.add_argument("--cols", type=int, default=16,
                    help="simulated mesh columns")
    ap.add_argument("--app", default="matmul",
                    help="workload source spec, dispatched through the "
                         "traffic-generator registry (repro.core.workloads); "
                         + source_summary())
    ap.add_argument("--refs", type=int, default=100,
                    help="memory references per core")
    ap.add_argument("--seed", type=int, default=0,
                    help="trace-synthesis seed")
    ap.add_argument("--centralized", action="store_true",
                    help="paper-default centralized directory (hot spot!)")
    ap.add_argument("--no-migration", action="store_true",
                    help="disable L2 block migration")
    ap.add_argument("--pc-depth", type=int, default=None,
                    help="pending-completion queue depth per node (default: "
                         "SimConfig.pc_depth).  1 = the paper's single S14 "
                         "completion register (can livelock under S14 "
                         "backpressure); >1 enables the ejection guarantee "
                         "(docs/architecture.md)")
    ap.add_argument("--eject-age-threshold", type=int, default=None,
                    help="guaranteed-ejection age threshold (default: "
                         "SimConfig.eject_age_threshold): with an occupied "
                         "pending-completion queue, only flits that have "
                         "deflected at least this many times eject into the "
                         "spare capacity")
    ap.add_argument("--pallas-router", action="store_true",
                    help="run phase-2 arbitration through the Pallas router "
                         "kernel (interpret mode off-TPU) instead of the "
                         "XLA reference oracle")
    ap.add_argument("--serial", action="store_true",
                    help="run the golden-model serial simulator instead of "
                         "the planner")
    ap.add_argument("--backend", choices=BACKENDS, default="auto",
                    help="pin the planner's backend for every bucket: "
                         "'sweep' (vmapped scenario batch), 'sharded' (2-D "
                         "spatial shard_map), 'composed' (batched shard_map "
                         "over a scenario x rows x cols device mesh); "
                         "'auto' lets the cost model choose.  A pinned "
                         "backend that is structurally impossible degrades "
                         "to sweep with a note")
    ap.add_argument("--sharded", action="store_true",
                    help="DEPRECATED legacy alias for --backend sharded "
                         "(emits a DeprecationWarning; will be removed)")
    ap.add_argument("--sweep", action="store_true",
                    help="batched sweep mode: run the --apps x --seeds "
                         "cross-product as one plan (default backend: "
                         "sweep; combine with --backend to override)")
    ap.add_argument("--plan", default=None, metavar="MANIFEST",
                    help="scenario manifest: JSON file path, inline JSON, or "
                         "compact 'ROWSxCOLS[:APP][:SEED[:REFS]];...' items "
                         "(APP = any registry source spec); mixed mesh "
                         "shapes allowed (repro.core.engine)")
    ap.add_argument("--zoo", default=None, metavar="FAMILY",
                    help="run a registered scenario-zoo family "
                         "(repro.core.zoo) through the planner: 'FAMILY' or "
                         "'FAMILY:refs=N,seeds=0+1,meshes=4x4+8x8'; "
                         "'--zoo list' prints the registered families and "
                         "exits")
    ap.add_argument("--apps", default=None,
                    help="comma list of apps for --sweep (default: --app)")
    ap.add_argument("--seeds", default=None,
                    help="comma list of seeds for --sweep (default: --seed)")
    ap.add_argument("--state-dtype", choices=("wide", "packed"),
                    default="wide",
                    help="SimState storage layout: 'wide' stores every "
                         "field as int32; 'packed' narrows each field to "
                         "the smallest dtype its config-derived bounds "
                         "allow (int8/int16), roughly halving resident "
                         "state bytes with bit-identical results (compute "
                         "still happens in int32; see docs/architecture.md)")
    ap.add_argument("--mem-budget", default=None, metavar="BYTES",
                    help="per-device resident-state budget for the planner "
                         "(bytes, optional K/M/G/T suffix, e.g. '512M'; "
                         "default: $REPRO_MEM_BUDGET or unlimited).  "
                         "Candidate backends over budget are dropped — "
                         "composed re-tiles toward deeper spatial splits — "
                         "and a plan that cannot fit fails fast with the "
                         "required bytes in the error")
    ap.add_argument("--chunk", type=int, default=8,
                    help="simulated cycles per device-loop termination check")
    ap.add_argument("--max-cycles", type=int, default=200_000,
                    help="hard cycle cap per scenario")
    ap.add_argument("--json", default=None,
                    help="also write the result payload to this file")
    return ap


def main(argv=None) -> None:
    """Launcher entry point; ``argv`` defaults to ``sys.argv[1:]``
    (injectable for tests)."""
    ap = build_parser()
    args = ap.parse_args(argv)

    if args.sharded:
        warnings.warn(
            "--sharded is deprecated and will be removed; "
            "use --backend sharded instead",
            DeprecationWarning, stacklevel=2)
        print("warning: --sharded is deprecated; use --backend sharded",
              file=sys.stderr)

    if args.zoo == "list":
        from repro.core.zoo import zoo_summary
        print(zoo_summary())
        return

    modes = [m for m in ("serial", "sweep", "plan", "zoo") if getattr(args, m)]
    if len(modes) > 1:
        ap.error(f"choose at most one of --serial/--sweep/--plan/--zoo "
                 f"(got {modes})")
    if args.serial and (args.sharded or args.backend != "auto"):
        ap.error("--serial does not route through the planner; "
                 "--backend/--sharded do not apply")
    if args.sharded and args.backend not in ("auto", "sharded"):
        ap.error(f"--sharded conflicts with --backend {args.backend}")

    from repro.core import SimConfig
    kw = {}
    if args.pc_depth is not None:
        kw["pc_depth"] = args.pc_depth
    if args.eject_age_threshold is not None:
        kw["eject_age_threshold"] = args.eject_age_threshold
    cfg = SimConfig(rows=args.rows, cols=args.cols,
                    centralized_directory=args.centralized,
                    migration_enabled=not args.no_migration,
                    max_cycles=args.max_cycles,
                    use_pallas_router=args.pallas_router,
                    state_dtype_policy=args.state_dtype, **kw)

    if args.serial:
        from repro.core.ref_serial import SerialSim
        from repro.core.trace import resolve_trace
        tr = resolve_trace(cfg, args.app, args.refs, args.seed)
        t0 = time.time()
        stats = SerialSim(cfg, tr).run()
        stats["wall_s"] = round(time.time() - t0, 2)
        stats["nodes"] = cfg.num_nodes
        print(json.dumps(stats, indent=1))
        if args.json:
            with open(args.json, "w") as f:
                json.dump(stats, f)
        return

    from repro.core import engine
    if args.sweep or args.plan or args.zoo:
        engine.expose_host_devices()

    force = args.backend if args.backend != "auto" else None
    if args.sharded:
        force = "sharded"
    if args.zoo:
        from repro.core.zoo import expand_zoo
        scenarios = expand_zoo(args.zoo, base=cfg)
    elif args.plan:
        scenarios = engine.load_manifest(args.plan, base=cfg)
    elif args.sweep:
        apps = (args.apps or args.app).split(",")
        seeds = [int(x) for x in (args.seeds or str(args.seed)).split(",")]
        scenarios = [engine.make_scenario(cfg, app=a, seed=s,
                                          refs_per_core=args.refs)
                     for a in apps for s in seeds]
        force = force or "sweep"
    else:
        scenarios = [engine.make_scenario(cfg, app=args.app, seed=args.seed,
                                          refs_per_core=args.refs)]

    plan = engine.compile_plan(
        scenarios, force_backend=force,
        mem_budget=engine.parse_mem_budget(args.mem_budget))
    t0 = time.time()
    per_scenario = engine.execute_plan(plan, chunk=args.chunk)
    dt = time.time() - t0

    # payload schema follows the *mode*, not the scenario count: --sweep,
    # --plan and --zoo always emit the {plan, scenarios, ...} form, even
    # for a single scenario
    if not (args.sweep or args.plan or args.zoo):
        payload = dict(per_scenario[0])
        payload["wall_s"] = round(dt, 2)
        payload["nodes"] = scenarios[0].cfg.num_nodes
        payload["backend"] = plan.buckets[0].backend
        if plan.buckets[0].note:
            payload["backend_note"] = plan.buckets[0].note
    else:
        payload = {
            **({"zoo": args.zoo} if args.zoo else {}),
            "plan": plan.describe(),
            "scenarios": [
                {"rows": sc.cfg.rows, "cols": sc.cfg.cols, "app": sc.app,
                 "seed": sc.seed, **st}
                for sc, st in zip(scenarios, per_scenario)],
            "n_scenarios": len(scenarios),
            "wall_s": round(dt, 2),
            "scenarios_per_sec": round(len(scenarios) / dt, 3),
        }
    print(json.dumps(payload, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f)


if __name__ == "__main__":
    main()
