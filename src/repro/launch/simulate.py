"""NoC-simulation launcher (the paper's workload).

    PYTHONPATH=src python -m repro.launch.simulate --rows 16 --cols 16 \
        --app matmul --refs 100

Every mode (except ``--serial``) routes through the execution-plan layer
(:mod:`repro.core.engine`): scenarios are bucketed by structural config,
each bucket compiles once, and a cost model picks the batched-sweep or
spatially-sharded backend per bucket.

Batched multi-scenario sweep (one compiled program for all scenarios):
    ... --sweep --apps matmul,equake,mgrid --seeds 0,1
Spatial sharding over jax.devices() (falls back to the dense backend on a
single device or an indivisible mesh):
    ... --sharded
Heterogeneous plan — mixed mesh shapes/apps/knobs from a manifest (a JSON
file, inline JSON, or the compact ROWSxCOLS:APP:SEED[:REFS] grammar):
    ... --plan manifest.json
    ... --plan '8x8:matmul:0:50;16x16:equake:1:50'
"""
from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=16)
    ap.add_argument("--cols", type=int, default=16)
    ap.add_argument("--app", default="matmul")
    ap.add_argument("--refs", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--centralized", action="store_true",
                    help="paper-default centralized directory (hot spot!)")
    ap.add_argument("--no-migration", action="store_true")
    ap.add_argument("--serial", action="store_true",
                    help="run the golden-model serial simulator instead")
    ap.add_argument("--sharded", action="store_true",
                    help="force the spatial shard_map backend (single-device "
                         "runs fall back to the dense backend)")
    ap.add_argument("--sweep", action="store_true",
                    help="batched sweep: run apps x seeds scenarios in one "
                         "compiled program (repro.core.sweep)")
    ap.add_argument("--plan", default=None, metavar="MANIFEST",
                    help="scenario manifest: JSON file path, inline JSON, or "
                         "compact 'ROWSxCOLS:APP:SEED[:REFS];...' items; "
                         "mixed mesh shapes allowed (repro.core.engine)")
    ap.add_argument("--apps", default=None,
                    help="comma list of apps for --sweep (default: --app)")
    ap.add_argument("--seeds", default=None,
                    help="comma list of seeds for --sweep (default: --seed)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="simulated cycles per device-loop termination check")
    ap.add_argument("--max-cycles", type=int, default=200_000)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    modes = [m for m in ("serial", "sharded", "sweep", "plan")
             if getattr(args, m)]
    if len(modes) > 1:
        ap.error(f"choose at most one of --serial/--sharded/--sweep/--plan "
                 f"(got {modes})")

    from repro.core.config import SimConfig
    cfg = SimConfig(rows=args.rows, cols=args.cols,
                    centralized_directory=args.centralized,
                    migration_enabled=not args.no_migration,
                    max_cycles=args.max_cycles)

    if args.serial:
        from repro.core.ref_serial import SerialSim
        from repro.core.trace import app_trace, random_trace
        tr = (random_trace(cfg, args.refs, args.seed) if args.app == "random"
              else app_trace(cfg, args.app, args.refs, args.seed))
        t0 = time.time()
        stats = SerialSim(cfg, tr).run()
        stats["wall_s"] = round(time.time() - t0, 2)
        stats["nodes"] = cfg.num_nodes
        print(json.dumps(stats, indent=1))
        if args.json:
            with open(args.json, "w") as f:
                json.dump(stats, f)
        return

    from repro.core import engine
    if args.sweep or args.plan:
        engine.expose_host_devices()

    if args.plan:
        scenarios = engine.load_manifest(args.plan, base=cfg)
        force = None
    elif args.sweep:
        apps = (args.apps or args.app).split(",")
        seeds = [int(x) for x in (args.seeds or str(args.seed)).split(",")]
        scenarios = [engine.make_scenario(cfg, app=a, seed=s,
                                          refs_per_core=args.refs)
                     for a in apps for s in seeds]
        force = "sweep"
    else:
        scenarios = [engine.make_scenario(cfg, app=args.app, seed=args.seed,
                                          refs_per_core=args.refs)]
        force = "sharded" if args.sharded else None

    plan = engine.compile_plan(scenarios, force_backend=force)
    t0 = time.time()
    per_scenario = engine.execute_plan(plan, chunk=args.chunk)
    dt = time.time() - t0

    # payload schema follows the *mode*, not the scenario count: --sweep
    # and --plan always emit the {plan, scenarios, ...} form, even for a
    # single scenario
    if not (args.sweep or args.plan):
        payload = dict(per_scenario[0])
        payload["wall_s"] = round(dt, 2)
        payload["nodes"] = scenarios[0].cfg.num_nodes
        payload["backend"] = plan.buckets[0].backend
        if plan.buckets[0].note:
            payload["backend_note"] = plan.buckets[0].note
    else:
        payload = {
            "plan": plan.describe(),
            "scenarios": [
                {"rows": sc.cfg.rows, "cols": sc.cfg.cols, "app": sc.app,
                 "seed": sc.seed, **st}
                for sc, st in zip(scenarios, per_scenario)],
            "n_scenarios": len(scenarios),
            "wall_s": round(dt, 2),
            "scenarios_per_sec": round(len(scenarios) / dt, 3),
        }
    print(json.dumps(payload, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f)


if __name__ == "__main__":
    main()
