import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent (sharding
propagates, the program fits, collectives lower) and records the roofline
inputs: HLO FLOPs/bytes from ``compiled.cost_analysis()`` and collective
operand bytes parsed from the optimized HLO.

Usage:
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --out results/dryrun
    python -m repro.launch.dryrun --arch noc-sim --shape noc_1m --mesh multi
"""
import argparse
import json
import re
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.shapes import SHAPES, applicable
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.models.config import ModelConfig
from repro.parallel.sharding import tree_shardings
from repro.train.optim import OptConfig, init_opt
from repro.train.step import make_prefill_step, make_serve_step, make_train_step

# NoC-simulator cells: simulated router grid sizes (paper max = 43k cores;
# the sharded simulator goes to 16.7M)
NOC_SHAPES = {
    "noc_43k": (256, 256),       # >= the paper's 43,000-core maximum
    "noc_1m": (1024, 1024),
    "noc_16m": (4096, 4096),
}

COLLECTIVE_OP_RE = re.compile(
    r"=\s*(.*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}


def collective_bytes(hlo_text: str) -> dict:
    """Per-device communicated bytes per collective kind, from optimized HLO.

    CPU-backend HLO references operands by name only, so each collective is
    sized by its RESULT buffer (exact for all-reduce / permute / all-to-all;
    the received volume for all-gather; a lower bound for reduce-scatter).
    '-done' ops are skipped (their '-start' carries the type).
    """
    out = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_OP_RE.search(line)
        if not m:
            continue
        types, kind = m.group(1), m.group(2)
        toks = SHAPE_RE.findall(types)
        if not toks:
            continue
        dt, dims = toks[-1]            # result type (last of a start-tuple)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind] = out.get(kind, 0) + n * _DTYPE_BYTES[dt]
    return out


def _lower_lm(cfg, shp, mesh):
    a_params = api.abstract_params(cfg)
    s_params = tree_shardings(api.param_pspecs(cfg), mesh, a_params)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel.sharding import resolve_pspec
    repl = NamedSharding(mesh, P())

    if shp.kind == "train":
        opt = OptConfig()
        a_opt = jax.eval_shape(lambda p: init_opt(opt, p), a_params)
        # moments shard like their parameters
        from repro.train.optim import OptState
        s_opt_sh = OptState(mu=s_params, nu=s_params, step=repl)
        a_batch = api.input_specs(cfg, "train", shp.global_batch, shp.seq_len)
        s_batch = tree_shardings(api.input_pspecs(cfg, "train"), mesh, a_batch)
        fn = make_train_step(cfg, opt, mesh=mesh)
        jitted = jax.jit(fn, in_shardings=(s_params, s_opt_sh, s_batch),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(a_params, a_opt, a_batch)
    elif shp.kind == "prefill":
        a_batch = api.input_specs(cfg, "prefill", shp.global_batch, shp.seq_len)
        s_batch = tree_shardings(api.input_pspecs(cfg, "prefill"), mesh, a_batch)
        fn = make_prefill_step(cfg, mesh=mesh)
        jitted = jax.jit(fn, in_shardings=(s_params, s_batch))
        lowered = jitted.lower(a_params, a_batch)
    else:  # decode
        a_cache = api.abstract_cache(cfg, shp.global_batch, shp.seq_len)
        s_cache = tree_shardings(
            api.cache_pspecs(cfg, shp.global_batch, shp.seq_len), mesh,
            a_cache)
        a_tok = jax.ShapeDtypeStruct((shp.global_batch, 1), jnp.int32)
        s_tok = NamedSharding(mesh, resolve_pspec(
            P(("pod", "data"), None), mesh, (shp.global_batch, 1)))
        fn = make_serve_step(cfg)
        jitted = jax.jit(fn, in_shardings=(s_params, s_cache, s_tok),
                         donate_argnums=(1,))
        lowered = jitted.lower(a_params, a_cache, a_tok)

    return lowered


def _measure(compiled) -> dict:
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", -1)),
        "bytes": float(cost.get("bytes accessed", -1)),
        "collective_bytes": coll,
        "mem": {
            "argument": int(mem.argument_size_in_bytes),
            "output": int(mem.output_size_in_bytes),
            "temp": int(mem.temp_size_in_bytes),
            "alias": int(mem.alias_size_in_bytes),
            "code": int(mem.generated_code_size_in_bytes),
        },
        "_mem_obj": mem,
    }


#: per-family layer-probe plan: (unit sizes, units in the real model)
def _probe_plan(cfg):
    import dataclasses
    if cfg.family == "hybrid":
        return None   # already unrolled: HLO costs are per-layer-correct
    if cfg.family == "vlm":
        iv = cfg.cross_attn_interval
        mk = lambda u: dataclasses.replace(cfg, scan_layers=False,
                                           n_layers=u * iv)
        return (1, 2), cfg.n_layers // iv, mk
    if cfg.family == "audio":
        mk = lambda u: dataclasses.replace(cfg, scan_layers=False,
                                           n_layers=u, encoder_layers=u)
        return (2, 4), cfg.n_layers, mk
    mk = lambda u: dataclasses.replace(cfg, scan_layers=False, n_layers=u)
    return (2, 4), cfg.n_layers, mk


def _extrapolate(m1: dict, m2: dict, u1: int, u2: int, units: int) -> dict:
    """Linear per-unit extrapolation of probe costs to the real depth."""
    def ex(a, b):
        per = (b - a) / (u2 - u1)
        return max(a + (units - u1) * per, 0.0)
    coll = {}
    kinds = set(m1["collective_bytes"]) | set(m2["collective_bytes"])
    for k in kinds:
        coll[k] = ex(m1["collective_bytes"].get(k, 0),
                     m2["collective_bytes"].get(k, 0))
    return {"flops": ex(m1["flops"], m2["flops"]),
            "bytes": ex(m1["bytes"], m2["bytes"]),
            "collective_bytes": coll}


def lm_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    from repro.models import costs

    cfg = registry.get(arch)
    shp = SHAPES[shape_name]
    ok, why = applicable(cfg.family, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "skipped": True, "why": why}
    mesh = make_production_mesh(multi_pod=multi_pod)

    t0 = time.time()
    lowered = _lower_lm(cfg, shp, mesh)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    m = _measure(compiled)

    # layer probes: true per-layer bytes/collectives (scan bodies are
    # counted once by XLA cost analysis — DESIGN/EXPERIMENTS §Roofline)
    corrected = None
    plan = _probe_plan(cfg)
    probe_s = 0.0
    if plan is not None:
        (u1, u2), units, mk = plan
        try:
            tp = time.time()
            p1 = _measure(_lower_lm(mk(u1), shp, mesh).compile())
            p2 = _measure(_lower_lm(mk(u2), shp, mesh).compile())
            corrected = _extrapolate(p1, p2, u1, u2, units)
            probe_s = time.time() - tp
        except Exception as e:
            corrected = {"error": repr(e)[:500]}
    else:
        corrected = {"flops": m["flops"], "bytes": m["bytes"],
                     "collective_bytes": m["collective_bytes"]}

    devices = int(np.prod(mesh.devices.shape))
    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "devices": devices,
        "flops": m["flops"],
        "bytes": m["bytes"],
        "collective_bytes": m["collective_bytes"],
        "corrected": corrected,
        "analytic_flops_global": costs.cell_flops(
            cfg, shp.kind, shp.global_batch, shp.seq_len),
        "attn_hbm_topup_global": costs.attn_hbm_bytes(
            cfg, shp.kind, shp.global_batch, shp.seq_len),
        "mem": m["mem"],
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "tokens": shp.global_batch * (shp.seq_len if shp.kind != "decode"
                                      else 1),
        "kind": shp.kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "probe_s": round(probe_s, 1),
    }
    print(f"[dryrun] {arch} {shape_name} {'multi' if multi_pod else 'single'}"
          f" OK flops={res['flops']:.3e} "
          f"temp/device={res['mem']['temp']/2**30:.2f}GiB "
          f"compile={t_compile:.0f}s probes={probe_s:.0f}s")
    print("memory_analysis:", m["_mem_obj"])
    return res


def noc_cell(shape_name: str, multi_pod: bool) -> dict:
    import dataclasses

    from repro.core.config import SimConfig
    from repro.core.sharded import make_sharded_step, state_specs, to_grid
    from repro.core.state import init_state

    rows, cols = NOC_SHAPES[shape_name]
    cfg = SimConfig(rows=rows, cols=cols, addr_bits=24,
                    centralized_directory=False, dir_layout="home")
    mesh = make_production_mesh(multi_pod=multi_pod)
    row_axes = ("pod", "data") if multi_pod else ("data",)
    col_axes = ("model",)

    t0 = time.time()
    m = 200   # refs per core (paper's M)
    trace_sds = jax.ShapeDtypeStruct((cfg.num_nodes, m), jnp.int32)
    a_state = jax.eval_shape(
        lambda tr: to_grid(init_state(cfg, tr), cfg), trace_sds)
    geo_sds = (
        jax.ShapeDtypeStruct((rows, cols), jnp.int32),
        jax.ShapeDtypeStruct((rows, cols), jnp.int32),
        jax.ShapeDtypeStruct((rows, cols), jnp.int32),
        jax.ShapeDtypeStruct((rows, cols, 4), jnp.bool_),
    )
    from jax.sharding import NamedSharding, PartitionSpec as P
    sspec = state_specs(cfg, row_axes, col_axes)
    s_state = jax.tree.map(lambda p: NamedSharding(mesh, p), sspec,
                           is_leaf=lambda x: isinstance(x, P))
    gsh = NamedSharding(mesh, P(row_axes, col_axes))

    # attach shardings to the abstract inputs so lowering sees the real
    # distribution (ShapeDtypeStruct carries a sharding)
    sds = lambda a, sh: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=sh)
    a_state = jax.tree.map(sds, a_state, s_state)
    geo_sds = tuple(sds(g, gsh) for g in geo_sds)

    build = make_sharded_step(cfg, mesh, row_axes, col_axes)
    step = build(8)   # 8 simulated cycles per call
    lowered = step.lower(a_state, *geo_sds)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    # probe: a 1-cycle step gives true per-cycle costs (the 8-cycle scan
    # body is counted once by cost analysis); corrected = per-cycle x 8
    try:
        p1 = _measure(build(1).lower(a_state, *geo_sds).compile())
        corrected = {"flops": p1["flops"] * 8, "bytes": p1["bytes"] * 8,
                     "collective_bytes": {k: v * 8 for k, v in
                                          p1["collective_bytes"].items()}}
    except Exception as e:
        corrected = {"error": repr(e)[:500]}
    res = {
        "arch": "noc-sim", "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "devices": int(np.prod(mesh.devices.shape)),
        "sim_nodes": rows * cols, "cycles_per_call": 8,
        "flops": float(cost.get("flops", -1)),
        "bytes": float(cost.get("bytes accessed", -1)),
        "collective_bytes": coll,
        "corrected": corrected,
        "mem": {
            "argument": int(mem.argument_size_in_bytes),
            "output": int(mem.output_size_in_bytes),
            "temp": int(mem.temp_size_in_bytes),
            "alias": int(mem.alias_size_in_bytes),
            "code": int(mem.generated_code_size_in_bytes),
        },
        "kind": "sim",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }
    print(f"[dryrun] noc-sim {shape_name} "
          f"{'multi' if multi_pod else 'single'} OK "
          f"nodes={rows*cols} compile={t_compile:.0f}s")
    print("memory_analysis:", mem)
    return res


def run_cell(arch: str, shape: str, mesh_kind: str) -> dict:
    multi = mesh_kind == "multi"
    if arch == "noc-sim":
        return noc_cell(shape, multi)
    return lm_cell(arch, shape, multi)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for a in registry.ARCH_IDS:
            for s in SHAPES:
                for mk in ("single", "multi"):
                    cells.append((a, s, mk))
        for s in NOC_SHAPES:
            for mk in ("single", "multi"):
                cells.append(("noc-sim", s, mk))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape, args.mesh)]

    for a, s, mk in cells:
        path = outdir / f"{a}__{s}__{mk}.json"
        if args.skip_existing and path.exists():
            print(f"[dryrun] skip existing {path.name}")
            continue
        try:
            res = run_cell(a, s, mk)
        except Exception as e:  # record failures for triage
            res = {"arch": a, "shape": s, "mesh": mk, "error": repr(e)[:2000]}
            print(f"[dryrun] FAIL {a} {s} {mk}: {e}")
        path.write_text(json.dumps(res, indent=1))


if __name__ == "__main__":
    main()
