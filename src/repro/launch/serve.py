"""Serving launcher: batched greedy/sampled decoding with continuous
batching.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import api
from repro.serve.server import Request, Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = registry.reduced(args.arch) if args.reduced \
        else registry.get(args.arch)
    params = api.init_params(cfg, jax.random.key(args.seed))
    server = Server(cfg, params, slots=args.slots, cache_len=args.cache_len,
                    temperature=args.temperature, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              size=int(rng.integers(2, 9))).astype(np.int32)
        server.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))

    t0 = time.time()
    done = server.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.1f}s "
          f"({toks/max(dt,1e-9):.1f} tok/s) with {args.slots} slots")


if __name__ == "__main__":
    main()
