"""Sharding utilities: resolve alias / fallback PartitionSpecs on a mesh.

Model code writes specs with the alias ``DP = ("pod", "data")`` and may
give ordered alternatives (:class:`repro.models.params.Alt`).  Resolution:

1. filter alias axes down to those the mesh actually has;
2. among ``Alt`` alternatives pick the first whose sharded dims divide the
   array shape evenly;
3. as a final safety net, drop (replicate) any still-non-divisible dim.
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.params import Alt


UNC = P.UNCONSTRAINED


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None or entry is UNC:
        return 1
    if isinstance(entry, (tuple, list)):
        return math.prod(mesh.shape[a] for a in entry)
    return mesh.shape[entry]


def _filter_alias(spec: P, mesh: Mesh) -> P:
    names = set(mesh.axis_names)
    out = []
    for entry in spec:
        if entry is None or entry is UNC:
            out.append(entry)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            out.append(kept if kept else None)
        else:
            out.append(entry if entry in names else None)
    return P(*out)


def _divides(spec: P, shape, mesh: Mesh) -> bool:
    for dim, entry in zip(shape, spec):
        if dim % _axis_size(mesh, entry):
            return False
    return True


def _drop_bad(spec: P, shape, mesh: Mesh) -> P:
    out = []
    for i, entry in enumerate(spec):
        dim = shape[i] if i < len(shape) else 1
        out.append(entry if dim % _axis_size(mesh, entry) == 0 else None)
    return P(*out)


def resolve_pspec(spec, mesh: Mesh, shape=None) -> P:
    alts = spec if isinstance(spec, Alt) else (spec,)
    resolved = [_filter_alias(s, mesh) for s in alts]
    if shape is not None:
        for s in resolved:
            if _divides(s, shape, mesh):
                return s
        return _drop_bad(resolved[0], shape, mesh)
    return resolved[0]


def _is_spec(x) -> bool:
    return isinstance(x, (P, Alt))


def tree_shardings(spec_tree: Any, mesh: Mesh, shape_tree: Any = None):
    """Spec tree (+ optional matching ShapeDtypeStruct tree) -> shardings."""
    if shape_tree is None:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, resolve_pspec(s, mesh)),
            spec_tree, is_leaf=_is_spec)
    return jax.tree.map(
        lambda s, a: NamedSharding(mesh, resolve_pspec(s, mesh, a.shape)),
        spec_tree, shape_tree, is_leaf=_is_spec)


def tree_pspecs_resolved(spec_tree: Any, mesh: Mesh, shape_tree: Any = None):
    if shape_tree is None:
        return jax.tree.map(lambda s: resolve_pspec(s, mesh), spec_tree,
                            is_leaf=_is_spec)
    return jax.tree.map(lambda s, a: resolve_pspec(s, mesh, a.shape),
                        spec_tree, shape_tree, is_leaf=_is_spec)
