"""Batched serving: continuous-batching decode over a fixed-size KV cache.

A minimal but real serving engine: request queue -> slot allocator ->
prefill (per request) -> batched decode steps -> detokenized streams.
Slots map onto the batch dimension of a shared cache; finished requests
free their slot for the next queued prompt (continuous batching).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (P,) int32
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 cache_len: int = 256, temperature: float = 0.0,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.temperature = temperature
        self.rng = np.random.default_rng(seed)
        self.cache = api.init_cache(cfg, slots, cache_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self._decode = jax.jit(
            lambda p, c, t: api.decode_step(cfg, p, c, t))

    def submit(self, req: Request) -> None:
        self.queue.append(req)
        if not hasattr(self, "_all"):
            self._all: List[Request] = []
        self._all.append(req)

    def _admit(self) -> None:
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req
                # per-slot prefill: feed prompt tokens one step at a time
                # (keeps a single compiled decode fn; fine for short prompts)
                for tok in req.prompt[:-1]:
                    t = np.zeros((self.slots, 1), np.int32)
                    t[i, 0] = tok
                    _, self.cache = self._decode(self.params, self.cache,
                                                 jnp.asarray(t))
                req._next = int(req.prompt[-1])

    def step(self) -> None:
        """One batched decode step for all active slots."""
        self._admit()
        if not any(self.active):
            return
        toks = np.zeros((self.slots, 1), np.int32)
        for i, req in enumerate(self.active):
            if req is not None:
                toks[i, 0] = getattr(req, "_next", 0)
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        logits = np.asarray(logits, np.float32)
        for i, req in enumerate(self.active):
            if req is None:
                continue
            if self.temperature > 0:
                z = logits[i] / self.temperature
                z = z - z.max()
                prob = np.exp(z) / np.exp(z).sum()
                nxt = int(self.rng.choice(len(prob), p=prob))
            else:
                nxt = int(np.argmax(logits[i]))
            req.out.append(nxt)
            req._next = nxt
            if len(req.out) >= req.max_new:
                req.done = True
                self.active[i] = None   # free slot (continuous batching)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        for _ in range(max_steps):
            self.step()
            if not self.queue and not any(self.active):
                break
        return [r for r in getattr(self, "_all", []) if r.done]
