"""Jit'd public wrappers for the Pallas kernels with oracle dispatch.

``backend="ref"`` runs the pure-jnp oracle (XLA — also the default inside
the simulator so HLO cost analysis sees true FLOPs); ``backend="pallas"``
runs the Pallas kernel (``interpret=True`` on CPU, compiled on TPU).
"""
from __future__ import annotations

import jax

from . import ref
from .flash_attention import flash_attention_pallas
from .router_phase import router_arbitrate_pallas

_ON_TPU = jax.default_backend() == "tpu"


def arbitrate(age, valid, we, dc, dr, vp, backend: str = "ref"):
    """Phase-2 router arbitration. See :func:`repro.kernels.ref.arbitrate_ref`."""
    if backend == "ref":
        return ref.arbitrate_ref(age, valid, we, dc, dr, vp)
    return router_arbitrate_pallas(age, valid, we, dc, dr, vp,
                                   interpret=not _ON_TPU)


def attention(q, k, v, causal: bool = True, backend: str = "ref"):
    """Multi-head attention. See :func:`repro.kernels.ref.attention_ref`."""
    if backend == "ref":
        return ref.attention_ref(q, k, v, causal=causal)
    return flash_attention_pallas(q, k, v, causal=causal,
                                  interpret=not _ON_TPU)
