"""Pallas TPU kernel: fused Phase-2 router arbitration over VMEM tiles.

This is the simulator's hot loop (the paper's dominant GPU kernel,
``FltsPrtAsgnOrDef``).  TPU-native layout: candidate slots live on the
sublane axis (padded 5 -> 8) and routers on the lane axis (tiles of 128),
so one (8, 128) VMEM tile holds 128 routers' full arbitration state and the
age-priority "sort" is a branch-free 5-round greedy evaluated entirely in
vector registers — the Mosaic analogue of the paper's Priority-Sort block.

All operands are int32; the kernel is bit-exact against
:func:`repro.kernels.ref.arbitrate_ref` (tests sweep shapes in interpret
mode on CPU; compiled mode targets TPU v5e).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32 = jnp.int32

SLOTS = 8        # padded candidate slots (5 used: 4 ports + injection)
BLOCK_N = 128    # routers per tile (lane dimension)
NSENTINEL = -1


def _select_row(x, best, has):
    """x: (SLOTS, BN); best: (1, BN) row index -> (1, BN) gathered values."""
    rows = jax.lax.broadcasted_iota(I32, x.shape, 0)
    sel = jnp.where((rows == best) & has, x, 0)
    return jnp.sum(sel, axis=0, keepdims=True)


def _router_kernel(age_ref, valid_ref, we_ref, dc_ref, dr_ref, vp_ref,
                   assigned_ref, deflect_ref):
    age = age_ref[...]          # (SLOTS, BN)
    valid = valid_ref[...] > 0
    we = we_ref[...] > 0
    dc = dc_ref[...]
    dr = dr_ref[...]
    vp = vp_ref[...] > 0        # (SLOTS, BN); rows 0..3 hold the real ports

    slot_iota = jax.lax.broadcasted_iota(I32, age.shape, 0)
    key = jnp.where(valid, age * 8 + (7 - slot_iota), -1)

    # PMDR preference scores, one (1, BN) row per port (N=0,E=1,S=2,W=3)
    def port_valid(p):
        rows = jax.lax.broadcasted_iota(I32, vp.shape, 0)
        return jnp.sum(jnp.where(rows == p, vp.astype(I32), 0), axis=0,
                       keepdims=True) > 0

    vpN, vpE, vpS, vpW = (port_valid(p) for p in range(4))
    base = lambda p, ok: jnp.where(ok, 10 + p, 1000)
    scoreN = jnp.where(dr < 0, jnp.where(vpN, 1, 1000), base(0, vpN))
    scoreE = jnp.where(dc > 0, jnp.where(vpE, 0, 1000), base(1, vpE))
    scoreS = jnp.where(dr > 0, jnp.where(vpS, 1, 1000), base(2, vpS))
    scoreW = jnp.where(dc < 0, jnp.where(vpW, 0, 1000), base(3, vpW))
    # (scores broadcast (1,BN) port rows against (SLOTS,BN) candidates)

    def argmin4(e0, e1, e2, e3):
        m01 = jnp.minimum(e0, e1)
        m23 = jnp.minimum(e2, e3)
        m = jnp.minimum(m01, m23)
        # first index attaining the min (ties -> lowest port, matching ref)
        p = jnp.where(e3 == m, 3, 0)
        p = jnp.where(e2 == m, 2, p)
        p = jnp.where(e1 == m, 1, p)
        p = jnp.where(e0 == m, 0, p)
        return p.astype(I32)

    first_pref = argmin4(scoreN, scoreE, scoreS, scoreW)   # (SLOTS, BN)

    taken = [jnp.zeros_like(scoreN[:1] > 0) for _ in range(4)]  # 4 x (1, BN)
    done = ~valid
    assigned = jnp.full_like(age, NSENTINEL)
    deflect = jnp.zeros_like(valid)
    scores = [scoreN, scoreE, scoreS, scoreW]

    for _ in range(5):
        kk = jnp.where(done, -1, key)
        kmax = jnp.max(kk, axis=0, keepdims=True)           # (1, BN)
        has = kmax >= 0
        # best slot = first row attaining kmax
        is_max = (kk == kmax) & has
        rows = jax.lax.broadcasted_iota(I32, kk.shape, 0)
        best = jnp.min(jnp.where(is_max, rows, SLOTS), axis=0, keepdims=True)
        eff = [_select_row(scores[p], best, has)
               + taken[p].astype(I32) * 10000 for p in range(4)]
        port = argmin4(*eff)                                 # (1, BN)
        fp = _select_row(first_pref, best, has)
        wej = _select_row(we.astype(I32), best, has) > 0
        defl = wej | (port != fp)
        sel = (rows == best) & has
        assigned = jnp.where(sel, port, assigned)
        deflect = deflect | (sel & defl)
        for p in range(4):
            taken[p] = taken[p] | (has & (port == p))
        done = done | sel

    assigned_ref[...] = assigned
    deflect_ref[...] = deflect.astype(I32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def router_arbitrate_pallas(age, valid, we, dc, dr, vp, *, interpret=True):
    """Pallas entry point.  All args (N, S)/(N, 4) as in ``arbitrate_ref``;
    returns (assigned (N,S) int32, deflect (N,S) bool)."""
    n, s_ = age.shape
    assert s_ <= SLOTS
    pad_n = (-n) % BLOCK_N

    def prep(x, rows, fill=0):
        x = x.astype(I32)
        x = jnp.pad(x, ((0, pad_n), (0, rows - x.shape[1])),
                    constant_values=fill)
        return x.T                                  # (rows, N_pad)

    age_t = prep(age, SLOTS)
    valid_t = prep(valid.astype(I32), SLOTS)
    we_t = prep(we.astype(I32), SLOTS)
    dc_t = prep(dc, SLOTS)
    dr_t = prep(dr, SLOTS)
    vp_t = prep(vp.astype(I32), SLOTS)

    n_pad = age_t.shape[1]
    grid = (n_pad // BLOCK_N,)
    spec = pl.BlockSpec((SLOTS, BLOCK_N), lambda i: (0, i))
    assigned, deflect = pl.pallas_call(
        _router_kernel,
        grid=grid,
        in_specs=[spec] * 6,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((SLOTS, n_pad), jnp.int32),
                   jax.ShapeDtypeStruct((SLOTS, n_pad), jnp.int32)],
        interpret=interpret,
    )(age_t, valid_t, we_t, dc_t, dr_t, vp_t)
    return (assigned.T[:n, :s_].astype(I32),
            deflect.T[:n, :s_] > 0)
