"""Pallas TPU kernel: blocked causal attention (online softmax).

Used by the LM stack for training/prefill when
``ModelConfig.use_pallas_attention`` is set.  Tiles: (BLOCK_Q x head_dim)
query tiles resident in VMEM stream over (BLOCK_K x head_dim) key/value
tiles; running max/denominator keep the softmax numerically exact.
Oracle: :func:`repro.kernels.ref.attention_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, block_q,
                 block_k, seq_k):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale         # (block_q, d)

    m = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q, 1), jnp.float32)
    acc = jnp.zeros_like(q)

    num_k = seq_k // block_k

    def body(kj, carry):
        m, l, acc = carry
        # leading unit dims indexed with dslice(0, 1): plain python ints in
        # a pl.load index tuple crash interpret mode on jax 0.4.x
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(0, 1),
                            pl.dslice(kj * block_k, block_k),
                            slice(None)))[0, 0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(0, 1),
                            pl.dslice(kj * block_k, block_k),
                            slice(None)))[0, 0].astype(jnp.float32)
        s = q @ k.T                                      # (block_q, block_k)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            kpos = kj * block_k + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_new = acc * alpha + p @ v
        return m_new, l_new, acc_new

    if causal:
        # only key blocks at or before this query block contribute
        upper = jnp.minimum(num_k, (qi + 1) * block_q // block_k
                            + (1 if block_q % block_k else 0))
        upper = jnp.maximum(upper, 1)
    else:
        upper = num_k
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m, l, acc))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, block_q=DEFAULT_BLOCK_Q,
                           block_k=DEFAULT_BLOCK_K, interpret=True):
    """q: (B, H, S, D); k, v: (B, H, T, D).  Returns (B, H, S, D)."""
    b, h, s, d = q.shape
    t = k.shape[2]
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    scale = 1.0 / (d ** 0.5)
    grid = (b, h, s // block_q)

    kernel = functools.partial(_attn_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k, seq_k=t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, t, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, t, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
