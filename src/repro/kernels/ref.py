"""Pure-jnp oracles for the Pallas kernels.

``arbitrate_ref`` is the semantic definition of the paper's Phase-2 router
arbitration (age-priority sort + PMDR port selection + deflection) — the
vectorized simulator calls it directly, and the Pallas kernel in
:mod:`repro.kernels.router_phase` must match it bit-for-bit.

``attention_ref`` is the oracle for the blocked flash-attention kernel.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

I32 = jnp.int32


def arbitrate_ref(age: jnp.ndarray, valid: jnp.ndarray, we: jnp.ndarray,
                  dc: jnp.ndarray, dr: jnp.ndarray,
                  vp: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Age-priority greedy port assignment for all routers at once.

    Args:
      age:   (N, S) candidate flit ages (S=5: 4 input ports + injection).
      valid: (N, S) bool candidate present.
      we:    (N, S) bool candidate wanted to eject but was refused (S11/S14).
      dc:    (N, S) dst_col - col  (sign gives the desired X direction).
      dr:    (N, S) dst_row - row.
      vp:    (N, 4) bool port physically exists (mesh edges).

    Returns:
      assigned: (N, S) port index in 0..3, or -1 for invalid candidates.
      deflect:  (N, S) bool — candidate did not get its first preference.
    """
    n, s_ = age.shape
    ports = jnp.arange(4, dtype=I32)
    slot = jnp.arange(s_, dtype=I32)

    # priority key: age desc, slot asc (injection = last slot, loses ties)
    key = jnp.where(valid, age * 8 + (s_ + 2 - slot), -1)

    # PMDR preference scores (S9): lower = preferred.  A desired direction
    # only scores if the port exists (matches serial `_prefs` vp filter; for
    # in-mesh destinations the desired port always exists).
    score = jnp.broadcast_to(10 + ports[None, None, :], (n, s_, 4))
    score = score.at[:, :, 1].set(jnp.where(dc > 0, 0, score[:, :, 1]))
    score = score.at[:, :, 3].set(jnp.where(dc < 0, 0, score[:, :, 3]))
    score = score.at[:, :, 2].set(jnp.where(dr > 0, 1, score[:, :, 2]))
    score = score.at[:, :, 0].set(jnp.where(dr < 0, 1, score[:, :, 0]))
    score = jnp.where(vp[:, None, :], score, 1000)
    first_pref = jnp.argmin(score, axis=2).astype(I32)

    taken = jnp.zeros((n, 4), bool)
    done = ~valid
    assigned = jnp.full((n, s_), -1, I32)
    deflect = jnp.zeros((n, s_), bool)
    for _ in range(s_):
        kk = jnp.where(done, -1, key)
        best = jnp.argmax(kk, axis=1)
        has = jnp.max(kk, axis=1) >= 0
        bscore = jnp.take_along_axis(score, best[:, None, None].repeat(4, 2),
                                     axis=1)[:, 0, :]
        eff = bscore + taken.astype(I32) * 10000
        port = jnp.argmin(eff, axis=1).astype(I32)
        onehot_b = (slot[None, :] == best[:, None]) & has[:, None]
        onehot_p = (ports[None, :] == port[:, None]) & has[:, None]
        assigned = jnp.where(onehot_b, port[:, None], assigned)
        fp = jnp.take_along_axis(first_pref, best[:, None], axis=1)[:, 0]
        wej = jnp.take_along_axis(we, best[:, None], axis=1)[:, 0]
        defl = wej | (port != fp)
        deflect = jnp.where(onehot_b, defl[:, None], deflect)
        taken = taken | onehot_p
        done = done | onehot_b
    return assigned, deflect


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True, scale: float | None = None
                  ) -> jnp.ndarray:
    """Reference attention. q,k,v: (B, H, S, D) / (B, H, T, D)."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        s_, t_ = logits.shape[-2:]
        mask = jnp.tril(jnp.ones((s_, t_), bool), t_ - s_)
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w, v.astype(jnp.float32)).astype(q.dtype)
