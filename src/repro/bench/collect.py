"""Metric collectors: turn simulator outputs into gated ``Metric`` lists.

Shared by the benchmark modules so the same network-health counters
(deflection rate, ejection-latency proxy, recovered drops — the
Ausavarungnirun-style deflection-routing health surface) and the same
timing conventions land in every ``BENCH_<area>.json`` under the same
names.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core.sim import aggregate_stats, network_health

from .schema import Metric

__all__ = ["health_metrics", "timing_metric", "ratio_metric",
           "count_metric", "flag_metric"]

#: default slack for deterministic event counters: the simulator is
#: bit-exact for a fixed (config, trace, seed), so any drift is a real
#: behavior change — a whisker of slack only guards float rounding in
#: derived ratios.
COUNT_SLACK = 0.0
RATIO_SLACK = 0.02
#: speedup ratios divide two same-host wall clocks, which makes them far
#: more portable across machines than either wall clock alone — gate
#: them, but with room for CI-runner noise.
SPEEDUP_SLACK = 0.5


def health_metrics(stats: Sequence[Dict[str, int]], prefix: str,
                   tags: Optional[Dict[str, str]] = None) -> List[Metric]:
    """Network-health metrics from per-scenario ``stats`` dicts.

    Args:
        stats: per-scenario statistics (``run``/``stats_list`` output);
            aggregated with :func:`repro.core.sim.aggregate_stats`.
        prefix: metric-name prefix, e.g. ``"plan"`` →
            ``plan.deflection_rate``.
        tags: context tags stamped on every emitted metric.

    Counters gate at zero slack (deterministic); derived ratios carry
    :data:`RATIO_SLACK` for rounding.
    """
    agg = aggregate_stats(list(stats))
    h = network_health(agg)
    t = dict(tags or {})
    return [
        Metric(f"{prefix}.deflection_rate", round(h["deflection_rate"], 6),
               unit="ratio", direction="lower", slack=RATIO_SLACK, tags=t),
        Metric(f"{prefix}.hops_per_flit", round(h["hops_per_flit"], 4),
               unit="hops/flit", direction="lower", slack=RATIO_SLACK,
               tags=t),
        Metric(f"{prefix}.deflections_per_flit",
               round(h["deflections_per_flit"], 4), unit="defl/flit",
               direction="lower", slack=RATIO_SLACK, tags=t),
        Metric(f"{prefix}.drops_recovered", h["drops_recovered"],
               unit="count", direction="lower", slack=COUNT_SLACK, tags=t),
        Metric(f"{prefix}.stray_responses", h["stray_responses"],
               unit="count", direction="lower", slack=COUNT_SLACK, tags=t),
    ]


def timing_metric(name: str, seconds: float, **kw) -> Metric:
    """A raw wall-clock measurement: informational (``gate=False``) —
    absolute times do not transfer between hosts; keyword args ``kw``
    pass through to :class:`Metric`."""
    kw.setdefault("unit", "s")
    kw.setdefault("direction", "lower")
    kw.setdefault("gate", False)
    return Metric(name, round(float(seconds), 4), **kw)


def ratio_metric(name: str, value: float, **kw) -> Metric:
    """A speedup/throughput *ratio*: gated with :data:`SPEEDUP_SLACK`
    (portable across hosts because both sides share the host's speed);
    ``kw`` passes through to :class:`Metric`."""
    kw.setdefault("unit", "x")
    kw.setdefault("direction", "higher")
    kw.setdefault("slack", SPEEDUP_SLACK)
    return Metric(name, round(float(value), 4), **kw)


def count_metric(name: str, value: int, **kw) -> Metric:
    """A deterministic event count (cycles, compiles, scenarios): gated
    at zero slack by default; ``kw`` passes through to :class:`Metric`."""
    kw.setdefault("unit", "count")
    kw.setdefault("direction", "lower")
    kw.setdefault("slack", COUNT_SLACK)
    return Metric(name, int(value), **kw)


def flag_metric(name: str, ok: bool, **kw) -> Metric:
    """A boolean invariant (``bit_identical``, ``all_finished``): gated,
    1 is good; ``kw`` passes through to :class:`Metric`."""
    kw.setdefault("unit", "bool")
    kw.setdefault("direction", "higher")
    kw.setdefault("slack", 0.0)
    return Metric(name, int(bool(ok)), **kw)
