"""The one benchmark entry contract.

Every benchmark module under ``benchmarks/`` declares a module-level
``BENCH = Benchmark(...)`` and a two-line ``main``::

    BENCH = Benchmark(area="sweep", title="...", add_args=add_args,
                      run=run_bench, smoke={"rows": 8, "cols": 8})

    def main(argv=None):
        return bench_main(BENCH, argv)

``bench_main`` gives every benchmark the same surface — the harness
(``benchmarks/run.py``), the regression gate (``scripts/bench_gate.py``)
and CI all invoke benchmarks uniformly through it:

* ``--smoke`` — switch the parser defaults to the benchmark's declared
  smoke tier (explicit flags still win: smoke only changes *defaults*);
* ``--out PATH`` — write the :class:`~repro.bench.schema.BenchReport`
  JSON (the ``BENCH_<area>.json`` shape the gate consumes);
* ``--json PATH`` — legacy flag: write the benchmark's raw payload dict
  (kept so pre-contract invocations keep working).

The benchmark's ``run`` callable does the work and returns the report;
``bench_main`` owns parsing, rendering and writing.
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Callable, Dict, List, Optional

from .schema import BenchReport

__all__ = ["Benchmark", "bench_main", "add_common_args"]


@dataclasses.dataclass(frozen=True)
class Benchmark:
    """One benchmark's registration under the shared entry contract.

    Attributes:
        area: short slug; the baseline file is ``BENCH_<area>.json``.
        title: one-line description for ``benchmarks/run.py --list``.
        add_args: callback adding the benchmark's own flags to an
            ``argparse.ArgumentParser``.
        run: ``run(args) -> BenchReport`` — the measurement itself.
        smoke: parser-default overrides applied when ``--smoke`` is
            given (CI tier: small meshes, few seeds, minutes not hours).
        gated: whether ``bench_gate.py --smoke`` checks this area
            against a committed repo-root baseline.
    """

    area: str
    title: str
    add_args: Callable[[argparse.ArgumentParser], None]
    run: Callable[[argparse.Namespace], BenchReport]
    smoke: Dict = dataclasses.field(default_factory=dict)
    gated: bool = True


def add_common_args(ap: argparse.ArgumentParser) -> None:
    """Install the contract's shared flags on parser ``ap``
    (``--smoke`` / ``--out`` / legacy ``--json``)."""
    ap.add_argument("--smoke", action="store_true",
                    help="smoke tier: switch defaults to a small, "
                         "CI-sized configuration (explicit flags still "
                         "override)")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the BenchReport JSON here "
                         "(the BENCH_<area>.json schema bench_gate.py "
                         "diffs against baselines)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="legacy: write the raw payload dict here")


def build_parser(bench: Benchmark) -> argparse.ArgumentParser:
    """The benchmark's full parser: its own flags + the common ones."""
    ap = argparse.ArgumentParser(description=bench.title)
    bench.add_args(ap)
    add_common_args(ap)
    return ap


def parse_bench_args(bench: Benchmark,
                     argv: Optional[List[str]] = None) -> argparse.Namespace:
    """Two-pass parse: detect ``--smoke`` first, swap in the smoke-tier
    defaults for benchmark ``bench``, then parse ``argv`` for real — so
    an explicit flag always beats the smoke default."""
    ap = build_parser(bench)
    pre, _ = ap.parse_known_args(argv)
    if pre.smoke and bench.smoke:
        ap.set_defaults(**bench.smoke)
    return ap.parse_args(argv)


def bench_main(bench: Benchmark,
               argv: Optional[List[str]] = None) -> BenchReport:
    """Uniform benchmark entry point: parse ``argv`` (two-pass smoke
    handling), run benchmark ``bench``, print the metric table, honor
    ``--out``/``--json``, and return the report."""
    args = parse_bench_args(bench, argv)
    report = bench.run(args)
    report.meta.setdefault("smoke", bool(args.smoke))
    print(report.render())
    if args.out:
        report.write(args.out)
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump(report.raw, f)
    return report
