"""Benchmark metrics contract: ``Metric`` + ``BenchReport``.

Every benchmark in this repo emits a :class:`BenchReport` — a flat list
of named, unit-tagged, direction-aware :class:`Metric` values — instead
of free-form prints.  The committed ``BENCH_<area>.json`` baselines at
the repo root are serialized reports; ``scripts/bench_gate.py`` diffs a
fresh run against them with per-metric slack (see
:mod:`repro.bench.gate` and ``docs/benchmarks.md``).

Design rules:

* a metric's *name* is stable — renaming one is a baseline-breaking
  change (the gate reports it as a vanished metric);
* ``direction`` says which way is better, so the gate only fails on
  drift in the *bad* direction — improvements are reported, not failed;
* ``slack`` is the tolerated relative drift in the bad direction
  (absolute when the baseline value is 0, where relative drift is
  undefined);
* ``gate=False`` marks informational metrics (raw wall-clock times,
  which vary across hosts) that are tracked in the trend table but never
  fail CI — portable *ratios* (speedups) and deterministic *counts*
  (cycles, compiles, drops) are the gated surface.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

__all__ = ["Metric", "BenchReport", "SCHEMA_VERSION"]

#: bump when the on-disk JSON layout changes incompatibly
SCHEMA_VERSION = 1

_DIRECTIONS = ("higher", "lower")

Num = Union[int, float]


@dataclasses.dataclass(frozen=True)
class Metric:
    """One measured value with enough context to gate and trend it.

    Args:
        name: stable dotted identifier, e.g. ``"wedge.completion_cycles"``
            — unique within its report's area.
        value: the measurement (int or float; bools are recorded as 0/1).
        unit: human unit label (``"s"``, ``"scenarios/s"``, ``"cycles"``,
            ``"count"``, ``"ratio"``, ``"bool"``, ...).
        direction: ``"higher"`` or ``"lower"`` — which way is *better*.
        slack: tolerated relative drift in the bad direction before the
            gate fails (``0.5`` = fails past 50% worse than baseline).
            Interpreted as an absolute allowance when the baseline value
            is exactly 0.
        gate: when ``False`` the metric is informational — trended but
            never failed (use for host-dependent raw wall times).
        tags: free-form context (``mesh``, ``backend``, ``app``, ...)
            used for display and trend grouping, never for matching.
    """

    name: str
    value: Num
    unit: str = "count"
    direction: str = "lower"
    slack: float = 0.0
    gate: bool = True
    tags: Dict[str, str] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.name:
            raise ValueError("metric name must be non-empty")
        if self.direction not in _DIRECTIONS:
            raise ValueError(
                f"metric {self.name!r}: direction must be one of "
                f"{_DIRECTIONS}, got {self.direction!r}")
        if isinstance(self.value, bool):
            object.__setattr__(self, "value", int(self.value))
        if not isinstance(self.value, (int, float)) or \
                not math.isfinite(self.value):
            raise ValueError(f"metric {self.name!r}: value must be a "
                             f"finite number, got {self.value!r}")
        if self.slack < 0:
            raise ValueError(f"metric {self.name!r}: slack must be >= 0")

    def to_dict(self) -> Dict:
        """JSON-ready dict (inverse of :meth:`from_dict`)."""
        d = dataclasses.asdict(self)
        if not d["tags"]:
            d.pop("tags")
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "Metric":
        """Rebuild a metric from :meth:`to_dict` output (validates)."""
        return cls(**d)


class BenchReport:
    """One benchmark run's emitted metrics + context, JSON round-trippable.

    Args:
        area: short area slug — baselines live at ``BENCH_<area>.json``.
        meta: run context (``smoke`` flag, key parameters, host notes).
        metrics: the measurements, in emission order.
        raw: the benchmark's legacy free-form payload dict, carried for
            debugging and the ``--json`` compatibility flag; the gate
            never reads it.
    """

    def __init__(self, area: str, meta: Optional[Dict] = None,
                 metrics: Sequence[Metric] = (), raw: Optional[Dict] = None):
        if not area:
            raise ValueError("report area must be non-empty")
        self.area = area
        self.meta = dict(meta or {})
        self.metrics: List[Metric] = []
        self.raw = dict(raw or {})
        seen = set()
        for m in metrics:
            if m.name in seen:
                raise ValueError(f"duplicate metric {m.name!r} in report "
                                 f"{area!r}")
            seen.add(m.name)
            self.metrics.append(m)

    # -- building -----------------------------------------------------
    def add(self, name: str, value: Num, **kw) -> Metric:
        """Append a new :class:`Metric` (kwargs as in ``Metric``);
        duplicate names raise."""
        m = Metric(name=name, value=value, **kw)
        if self.metric(name) is not None:
            raise ValueError(f"duplicate metric {name!r} in report "
                             f"{self.area!r}")
        self.metrics.append(m)
        return m

    def extend(self, metrics: Sequence[Metric]) -> None:
        """Append pre-built metrics (same duplicate check as :meth:`add`)."""
        for m in metrics:
            if self.metric(m.name) is not None:
                raise ValueError(f"duplicate metric {m.name!r} in report "
                                 f"{self.area!r}")
            self.metrics.append(m)

    # -- access -------------------------------------------------------
    def metric(self, name: str) -> Optional[Metric]:
        """The metric called ``name``, or ``None``."""
        for m in self.metrics:
            if m.name == name:
                return m
        return None

    def names(self) -> Tuple[str, ...]:
        """Metric names in emission order."""
        return tuple(m.name for m in self.metrics)

    def __eq__(self, other):
        return (isinstance(other, BenchReport)
                and self.area == other.area and self.meta == other.meta
                and self.metrics == other.metrics and self.raw == other.raw)

    def __repr__(self):
        return (f"BenchReport(area={self.area!r}, "
                f"metrics={len(self.metrics)})")

    # -- serialization ------------------------------------------------
    def to_dict(self) -> Dict:
        """JSON-ready dict (inverse of :meth:`from_dict`)."""
        return {
            "schema_version": SCHEMA_VERSION,
            "area": self.area,
            "meta": self.meta,
            "metrics": [m.to_dict() for m in self.metrics],
            **({"raw": self.raw} if self.raw else {}),
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "BenchReport":
        """Rebuild a report from :meth:`to_dict` output (validates every
        metric; unknown schema versions raise)."""
        ver = d.get("schema_version", SCHEMA_VERSION)
        if ver > SCHEMA_VERSION:
            raise ValueError(f"BENCH schema version {ver} is newer than "
                             f"this checkout understands ({SCHEMA_VERSION})")
        return cls(area=d["area"], meta=d.get("meta", {}),
                   metrics=[Metric.from_dict(m) for m in d.get("metrics", [])],
                   raw=d.get("raw", {}))

    def to_json(self, indent: int = 1) -> str:
        """Serialize (stable layout; newline-terminated for clean diffs)."""
        return json.dumps(self.to_dict(), indent=indent) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "BenchReport":
        """Parse :meth:`to_json` output."""
        return cls.from_dict(json.loads(text))

    def write(self, path: str) -> None:
        """Write the report to ``path`` as JSON."""
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def read(cls, path: str) -> "BenchReport":
        """Load a report previously written with :meth:`write`."""
        with open(path) as f:
            return cls.from_json(f.read())

    # -- display ------------------------------------------------------
    def render(self) -> str:
        """Human table: one row per metric (gated rows marked ``*``)."""
        rows = [f"== BENCH {self.area} "
                f"({'smoke' if self.meta.get('smoke') else 'full'} tier) =="]
        w = max([len(m.name) for m in self.metrics] or [4])
        for m in self.metrics:
            val = f"{m.value:g}"
            mark = "*" if m.gate else " "
            arrow = "^" if m.direction == "higher" else "v"
            tag = " ".join(f"{k}={v}" for k, v in m.tags.items())
            rows.append(f" {mark} {m.name:<{w}s} {val:>12s} {m.unit:<12s} "
                        f"{arrow} slack={m.slack:g}"
                        + (f"  [{tag}]" if tag else ""))
        return "\n".join(rows)
