"""Benchmark-telemetry subsystem: the metrics contract, collectors and
regression gate behind the repo's committed perf trajectory.

The pieces (see ``docs/benchmarks.md``):

* :mod:`repro.bench.schema` — ``Metric``/``BenchReport``: the JSON
  round-trippable contract every benchmark emits.
* :mod:`repro.bench.collect` — shared collectors (network-health
  counters from the ``STAT_NAMES`` surface, timing/ratio/count/flag
  conventions).
* :mod:`repro.bench.contract` — the one benchmark entry contract
  (``Benchmark`` + ``bench_main`` with common ``--smoke/--out/--json``).
* :mod:`repro.bench.gate` — direction-aware baseline diffing + trend
  rendering (driven by ``scripts/bench_gate.py``).

Import cost is deliberately tiny (stdlib + the pure-python core stats
helpers) so the gate script can parse and diff reports without paying a
jax import.
"""
from .schema import BenchReport, Metric, SCHEMA_VERSION
from .contract import Benchmark, bench_main
from .gate import (Finding, compare_reports, gate_passes, render_findings,
                   render_trend)

__all__ = [
    "Metric", "BenchReport", "SCHEMA_VERSION",
    "Benchmark", "bench_main",
    "Finding", "compare_reports", "gate_passes", "render_findings",
    "render_trend",
]
