"""Direction-aware baseline comparison + trend table for BenchReports.

The library behind ``scripts/bench_gate.py``: pure functions over
:class:`repro.bench.schema.BenchReport` pairs so every edge case
(missing baseline metric, newly added metric, regression beyond slack,
improvement) is unit-testable without running a benchmark.

Semantics (see ``docs/benchmarks.md``):

* only the *baseline's* gated metrics can fail the gate — the committed
  baseline is the contract, a fresh run is the candidate;
* a gated baseline metric missing from the fresh report is a failure
  (the measurement silently vanished);
* a metric present only in the fresh report is reported as ``new`` and
  never fails (it starts gating once a refreshed baseline commits it);
* drift in the *bad* direction beyond ``slack`` fails; drift in the good
  direction is reported as ``improvement`` (a refresh opportunity);
* when the baseline value is 0, relative drift is undefined and
  ``slack`` is applied as an absolute allowance.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .schema import BenchReport, Metric

__all__ = ["Finding", "compare_reports", "gate_passes", "render_findings",
           "render_trend"]

#: finding kinds, in display-severity order
_KINDS = ("regression", "vanished", "ok", "improvement", "new", "info")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One metric's verdict from :func:`compare_reports`.

    Attributes:
        name: the metric name.
        kind: ``"regression"`` | ``"vanished"`` | ``"ok"`` |
            ``"improvement"`` | ``"new"`` | ``"info"`` (ungated).
        base: baseline value (``None`` for ``new``).
        fresh: fresh value (``None`` for ``vanished``).
        rel: signed relative change ``(fresh-base)/|base|`` (``None``
            when undefined: zero baseline or a missing side).
        fails: whether this finding fails the gate.
        detail: one-line human explanation.
    """

    name: str
    kind: str
    base: Optional[float]
    fresh: Optional[float]
    rel: Optional[float]
    fails: bool
    detail: str

    def __post_init__(self):
        assert self.kind in _KINDS, self.kind


def _judge(base: Metric, fresh: Metric, slack_scale: float) -> Finding:
    """Direction-aware verdict for one (baseline, fresh) metric pair."""
    b, f = float(base.value), float(fresh.value)
    sign = 1.0 if base.direction == "lower" else -1.0   # +1: growth is bad
    rel = (f - b) / abs(b) if b else None
    slack = base.slack * slack_scale
    # bad_drift > slack fails: relative drift normally, absolute units
    # when the baseline is 0 (relative drift is undefined there)
    bad_drift = sign * (f - b) / abs(b) if b else sign * (f - b)
    if not base.gate:
        return Finding(base.name, "info", b, f, rel, False,
                       "informational (not gated)")
    if bad_drift > slack:
        return Finding(base.name, "regression", b, f, rel, True,
                       f"worse than baseline beyond slack "
                       f"({base.slack:g}{'' if b else ' abs'})")
    if (sign * (f - b)) < 0:
        return Finding(base.name, "improvement", b, f, rel, False,
                       "better than baseline — consider refreshing")
    return Finding(base.name, "ok", b, f, rel, False, "within slack")


def compare_reports(base: BenchReport, fresh: BenchReport,
                    slack_scale: float = 1.0) -> List[Finding]:
    """Diff ``fresh`` against the committed ``base`` report.

    Args:
        base: the committed baseline (its metrics define the contract).
        fresh: the candidate run to judge.
        slack_scale: multiplier applied to every baseline slack (CI can
            loosen a noisy host with ``--slack-scale 2`` without editing
            baselines).

    Returns one :class:`Finding` per union-of-names metric, baseline
    order first, fresh-only (``new``) metrics after.
    """
    if base.area != fresh.area:
        raise ValueError(f"area mismatch: baseline {base.area!r} vs "
                         f"fresh {fresh.area!r}")
    findings = []
    for bm in base.metrics:
        fm = fresh.metric(bm.name)
        if fm is None:
            findings.append(Finding(
                bm.name, "vanished", float(bm.value), None, None, bm.gate,
                "in baseline but missing from fresh run"
                + ("" if bm.gate else " (not gated)")))
            continue
        findings.append(_judge(bm, fm, slack_scale))
    for fm in fresh.metrics:
        if base.metric(fm.name) is None:
            findings.append(Finding(
                fm.name, "new", None, float(fm.value), None, False,
                "not in baseline yet — gates after a refresh"))
    return findings


def gate_passes(findings: Sequence[Finding]) -> bool:
    """``True`` when no finding fails (the CI exit-code predicate)."""
    return not any(f.fails for f in findings)


def _fmt(v: Optional[float]) -> str:
    return "—" if v is None else f"{v:g}"


def render_findings(area: str, findings: Sequence[Finding]) -> str:
    """Human table of one area's findings, worst first."""
    order = {k: i for i, k in enumerate(_KINDS)}
    rows = sorted(findings, key=lambda f: (not f.fails, order[f.kind]))
    w = max([len(f.name) for f in findings] or [4])
    out = [f"-- {area}: {sum(f.fails for f in findings)} failing / "
           f"{len(findings)} metrics --"]
    for f in rows:
        rels = "" if f.rel is None else f"{f.rel:+.1%}"
        flag = "FAIL" if f.fails else "    "
        out.append(f" {flag} {f.kind:<11s} {f.name:<{w}s} "
                   f"{_fmt(f.base):>12s} -> {_fmt(f.fresh):>12s} "
                   f"{rels:>8s}  {f.detail}")
    return "\n".join(out)


def render_trend(history: Sequence[Tuple[str, BenchReport]],
                 names: Optional[Sequence[str]] = None,
                 max_cols: int = 8) -> str:
    """Trend table: one row per metric, one column per historical report.

    Args:
        history: ``(label, report)`` pairs, oldest first (labels are
            typically abbreviated commit hashes, with the newest being
            the fresh run).
        names: metric names to show (default: the newest report's gated
            metrics, then its informational ones).
        max_cols: keep only the last ``max_cols`` history columns.
    """
    if not history:
        return "(no history)"
    history = list(history)[-max_cols:]
    newest = history[-1][1]
    if names is None:
        names = [m.name for m in newest.metrics if m.gate] + \
                [m.name for m in newest.metrics if not m.gate]
    labels = [lbl for lbl, _ in history]
    w = max([len(n) for n in names] or [4])
    cw = max([len(x) for x in labels] + [10])
    out = [f"trend ({newest.area}):",
           " " * (w + 3) + " ".join(f"{x:>{cw}s}" for x in labels)]
    for n in names:
        vals = []
        for _, rep in history:
            m = rep.metric(n)
            vals.append("—" if m is None else f"{m.value:g}")
        out.append(f"  {n:<{w}s} " + " ".join(f"{v:>{cw}s}" for v in vals))
    return "\n".join(out)
