"""Trace generation (paper §6.2.3).

The paper feeds the simulator "representative traces" produced by Multi2sim
for five applications (matmul, apsi, mgrid, wupwise, equake) with ``M``
(=200) address references per core, and notes Multi2sim cannot produce traces
beyond ~100 cores.  We reproduce the *representative trace* methodology with
parameterized per-application access-pattern models that scale to any core
count, plus uniform-random traffic and traces derived from an LM model's
layer schedule (so the trace source scales with the simulated machine, which
is exactly the capability gap the paper calls out).

A trace is an ``(num_nodes, M) int32`` array of byte addresses, ``-1`` padded.
"""
from __future__ import annotations

import numpy as np

from .config import SimConfig

__all__ = [
    "app_trace",
    "random_trace",
    "from_model_schedule",
    "stacked_traces",
    "TRACE_APPS",
]


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(np.random.PCG64(seed))


# ---------------------------------------------------------------------------
# Application models.  Each is characterized by:
#   stride         dominant access stride in bytes
#   p_shared       probability an access lands in the globally shared region
#   p_local        probability an access re-touches the node's hot set
#   hot_blocks     size of the node's hot set (in L2 blocks)
#   p_neighbour    probability of touching a mesh-neighbour's private region
#                  (stencil-style sharing)
# Values chosen to mimic the qualitative traffic mix of the SPEC-OMP codes
# the paper uses (matmul: heavy shared-B reuse; mgrid: stencil; equake:
# irregular sparse; wupwise: long strides; apsi: mixed).
# ---------------------------------------------------------------------------
TRACE_APPS = {
    "matmul": dict(stride=8, p_shared=0.45, p_local=0.35, hot_blocks=8, p_neighbour=0.05),
    "apsi": dict(stride=16, p_shared=0.20, p_local=0.50, hot_blocks=16, p_neighbour=0.10),
    "mgrid": dict(stride=8, p_shared=0.10, p_local=0.45, hot_blocks=12, p_neighbour=0.30),
    "wupwise": dict(stride=64, p_shared=0.25, p_local=0.40, hot_blocks=8, p_neighbour=0.10),
    "equake": dict(stride=4, p_shared=0.30, p_local=0.25, hot_blocks=24, p_neighbour=0.10),
}


def app_trace(cfg: SimConfig, app: str, refs_per_core: int = 200, seed: int = 0) -> np.ndarray:
    """Representative trace for one of the paper's five applications."""
    if app not in TRACE_APPS:
        raise ValueError(f"unknown app {app!r}; choose from {sorted(TRACE_APPS)}")
    p = TRACE_APPS[app]
    n = cfg.num_nodes
    stable = sum(ord(ch) * (i + 1) for i, ch in enumerate(app)) % 65536
    g = _rng(seed * 1_000_003 + stable)
    addr_space = 1 << cfg.addr_bits
    blk = cfg.cache.l2_block

    # Region layout: first quarter of the address space is shared, the rest
    # is divided into per-node private regions.
    shared_hi = addr_space // 4
    priv_size = max(blk * 4, (addr_space - shared_hi) // n)

    out = np.full((n, refs_per_core), -1, dtype=np.int64)
    for node in range(n):
        base = shared_hi + node * priv_size
        r, c = divmod(node, cfg.cols)
        neighbours = [nr * cfg.cols + nc
                      for nr, nc in ((r - 1, c), (r + 1, c), (r, c - 1), (r, c + 1))
                      if 0 <= nr < cfg.rows and 0 <= nc < cfg.cols]
        hot = base + (g.integers(0, max(1, priv_size // blk), p["hot_blocks"]) * blk)
        cursor = base
        kinds = g.random(refs_per_core)
        for i in range(refs_per_core):
            k = kinds[i]
            if k < p["p_shared"]:
                # shared region, zipf-ish: few very hot shared blocks
                zb = int(g.zipf(1.6)) % max(1, shared_hi // blk)
                a = zb * blk
            elif k < p["p_shared"] + p["p_local"]:
                a = int(hot[g.integers(0, len(hot))])
            elif k < p["p_shared"] + p["p_local"] + p["p_neighbour"] and neighbours:
                nb = neighbours[int(g.integers(0, len(neighbours)))]
                a = shared_hi + nb * priv_size + int(g.integers(0, priv_size // blk)) * blk
            else:
                cursor = base + (cursor - base + p["stride"]) % priv_size
                a = cursor
            out[node, i] = a % addr_space
    return out.astype(np.int32)


def stacked_traces(cfg: SimConfig, specs, default_refs: int = 200) -> np.ndarray:
    """Stack per-scenario traces into one ``(B, num_nodes, M)`` block for
    the batched sweep engine (:mod:`repro.core.sweep`).

    ``specs`` is an iterable of ``(app, seed)`` or ``(app, seed,
    refs_per_core)`` tuples, where ``app`` is a :data:`TRACE_APPS` name or
    ``"random"``.  Scenarios with fewer references are right-padded with
    ``-1`` — the trace-exhaustion sentinel — which is semantically
    identical to running them unpadded, so scenarios of different lengths
    can share one batch.
    """
    mats = []
    for sp in specs:
        app, seed = sp[0], sp[1]
        refs = sp[2] if len(sp) > 2 else default_refs
        t = (random_trace(cfg, refs, seed) if app == "random"
             else app_trace(cfg, app, refs, seed))
        mats.append(t)
    if not mats:
        raise ValueError("stacked_traces needs at least one scenario")
    m = max(t.shape[1] for t in mats)
    out = np.full((len(mats), cfg.num_nodes, m), -1, np.int32)
    for b, t in enumerate(mats):
        out[b, :, : t.shape[1]] = t
    return out


def random_trace(cfg: SimConfig, refs_per_core: int = 200, seed: int = 0) -> np.ndarray:
    """Uniform-random traffic (the paper's synthetic injector)."""
    g = _rng(seed)
    addr_space = 1 << cfg.addr_bits
    a = g.integers(0, addr_space, size=(cfg.num_nodes, refs_per_core), dtype=np.int64)
    # align to word
    return ((a >> 2) << 2).astype(np.int32)


def from_model_schedule(
    cfg: SimConfig,
    layer_params_bytes: int,
    d_model: int,
    n_layers: int,
    refs_per_core: int = 200,
    seed: int = 0,
) -> np.ndarray:
    """Derive an LCMP trace from an LM layer schedule.

    Nodes are tiled over (layer-shard, token-shard): node ``i`` repeatedly
    streams its weight shard (private, strided) and the activation blocks it
    exchanges with its layer neighbours (shared).  This replaces the paper's
    Multi2sim front-end, which could not scale past ~100 cores.
    """
    g = _rng(seed)
    n = cfg.num_nodes
    addr_space = 1 << cfg.addr_bits
    blk = cfg.cache.l2_block
    w_region = addr_space // 2
    act_region = addr_space - w_region

    shard = max(blk * 8, min(layer_params_bytes // max(1, n // n_layers), w_region // n))
    out = np.full((n, refs_per_core), -1, dtype=np.int64)
    act_blocks = max(1, (d_model * 2) // blk)  # one bf16 activation vector
    for node in range(n):
        layer = node % n_layers
        wbase = (node * shard) % max(blk, w_region - shard)
        abase = w_region + (layer * act_blocks * blk) % max(blk, act_region - act_blocks * blk)
        i = 0
        while i < refs_per_core:
            # stream a few weight blocks, then touch the activation interface
            for s in range(min(6, refs_per_core - i)):
                out[node, i] = wbase + ((i * blk) % shard)
                i += 1
            if i < refs_per_core:
                out[node, i] = abase + int(g.integers(0, act_blocks)) * blk
                i += 1
    return (out % addr_space).astype(np.int32)
