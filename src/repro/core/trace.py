"""Back-compat shim over :mod:`repro.core.workloads`.

Trace synthesis lives in the workloads package now — a traffic-generator
registry (:mod:`repro.core.workloads.base`) behind one source grammar,
with the app models in :mod:`repro.core.workloads.apps` and the
synthetic NoC patterns in :mod:`repro.core.workloads.patterns`.  This
module re-exports the historical surface (``app_trace``,
``app_trace_loop``, ``random_trace``, ``resolve_trace``,
``stacked_traces``, ``from_model_schedule``, ``TRACE_APPS``,
``valid_app``) so existing imports keep working; outputs are pinned
bit-identical to the pre-refactor generators by the golden digests in
``tests/test_workloads.py``.  New code should import from
:mod:`repro.core.workloads` directly.
"""
from __future__ import annotations

from .workloads import (TRACE_APPS, app_trace, app_trace_loop,
                        from_model_schedule, random_trace, resolve_trace,
                        stacked_traces, valid_app)

__all__ = [
    "app_trace",
    "app_trace_loop",
    "random_trace",
    "resolve_trace",
    "from_model_schedule",
    "stacked_traces",
    "TRACE_APPS",
    "valid_app",
]
