"""Serial golden-model simulator (the paper's §7.1 "serial version").

Pure numpy + Python loops, deliberately boring.  This file is the
*executable specification*: the vectorized JAX simulator in
:mod:`repro.core.sim` implements bit-identical semantics and is validated
against this model (paper §7.3 validates GPU-vs-serial the same way).

Semantic rules are labelled ``S<n>`` and referenced from the vectorized
implementation.

Per-cycle phase order (S1):
    1a. each node processes at most one completed inbound packet
    1b. each node steps its memory-access FSM (trace-driven)
    2.  each router arbitrates: eject -> inject -> age-priority port assign
    3.  flits move to neighbour input ports; ejected flit enters the reorder
        buffer; a fully-assembled packet becomes the node's pending
        completion for the next cycle's phase 1a.
Within a phase, nodes are independent (writes are conflict-free), so any
iteration order gives the same result — this is what makes the paper's
one-thread-per-router parallelization (and our vectorization) exact.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .config import (
    EJECT,
    FLITS_OF,
    INSTALL_L1_ONLY,
    INSTALL_L2,
    MSG_B2,
    MSG_DA,
    MSG_DR,
    MSG_DU,
    MSG_MIG_ACK,
    MSG_NACK,
    MSG_RA,
    MSG_REQ,
    MSG_REQ_FWD,
    MSG_WB,
    NUM_PORTS,
    PORT_E,
    PORT_N,
    PORT_S,
    PORT_W,
    ST_DONE,
    ST_IDLE,
    ST_L1_WAIT,
    ST_L2_WAIT,
    ST_WAIT_DATA,
    ST_WAIT_DIR,
    ST_WAIT_MEM,
    SimConfig,
)

STAT_NAMES = (
    "req_made", "req_rcvd", "reply_sent", "reply_rcvd", "trap",
    "redirection", "dir_search", "dir_update", "mem_req", "migrations",
    "migrations_done", "l1_hits", "l1_misses", "l2_local_hits",
    "l2_local_misses", "wb_sent", "wb_rcvd", "wb_miss", "flits_delivered",
    "deflections", "hops", "injected", "send_drop", "l2_install_drop",
    "stray",
)


@dataclasses.dataclass
class Flit:
    age: int
    src: int
    dst: int
    osrc: int      # original requester / DU owner payload / DR owner payload
    typ: int
    tag: int
    pkt: int
    fid: int
    nfl: int


class SerialSim:
    """Golden-model LCMP simulator (serial; semantics spec)."""

    def __init__(self, cfg: SimConfig, trace: np.ndarray):
        cfg.validate()
        self.cfg = cfg
        n = cfg.num_nodes
        assert trace.shape[0] == n
        self.trace = trace.astype(np.int64)
        ca = cfg.cache

        # --- per-node FSM ---
        self.st = np.zeros(n, np.int64)
        self.ctr = np.zeros(n, np.int64)
        self.tr_ptr = np.zeros(n, np.int64)
        self.pend_addr = np.full(n, -1, np.int64)
        self.install_mode = np.zeros(n, np.int64)
        self.pkt_ctr = np.zeros(n, np.int64)

        # --- caches (SoA) ---
        self.l1_tag = np.full((n, ca.l1_sets, ca.l1_ways), -1, np.int64)
        self.l1_lru = np.zeros((n, ca.l1_sets, ca.l1_ways), np.int64)
        self.l1_owner = np.full((n, ca.l1_sets, ca.l1_ways), -1, np.int64)
        self.l2_tag = np.full((n, ca.l2_sets, ca.l2_ways), -1, np.int64)
        self.l2_lru = np.zeros((n, ca.l2_sets, ca.l2_ways), np.int64)
        self.l2_mig = np.zeros((n, ca.l2_sets, ca.l2_ways), np.int64)
        self.l2_last_req = np.full((n, ca.l2_sets, ca.l2_ways), -1, np.int64)
        self.l2_streak = np.zeros((n, ca.l2_sets, ca.l2_ways), np.int64)
        self.lru_clock = np.zeros(n, np.int64)

        # --- directory (paper's "location array") ---
        self.dir_loc = np.full(cfg.dir_entries, -1, np.int64)

        # --- forwarding table (redirection) ---
        self.fwd_tag = np.full((n, cfg.fwd_entries), -1, np.int64)
        self.fwd_dst = np.full((n, cfg.fwd_entries), -1, np.int64)
        self.fwd_ptr = np.zeros(n, np.int64)

        # --- network ---
        self.inp: List[List[Optional[Flit]]] = [[None] * NUM_PORTS for _ in range(n)]
        # send queue holds whole packets (typ, dst, osrc, tag, pkt, nfl);
        # flits of the head packet are injected one per cycle (S2).
        self.sendq: List[List[Tuple[int, int, int, int, int, int]]] = [[] for _ in range(n)]
        self.q_fid = np.zeros(n, np.int64)   # flit cursor of the head packet

        # --- reorder buffer: per node, list of [src, pkt, typ, tag, osrc, nfl, count]
        self.rob: List[List[List[int]]] = [[] for _ in range(n)]
        # pending-completion queue: per node, FIFO of (typ, src, osrc, tag)
        # capped at cfg.pc_depth (depth 1 = the paper's single S14
        # register; deeper queues enable the ejection guarantee, see
        # phase2)
        self.pending: List[List[Tuple[int, int, int, int]]] = [[] for _ in range(n)]

        self.stats: Dict[str, int] = {k: 0 for k in STAT_NAMES}
        self.cycle = 0

    # -- geometry helpers ---------------------------------------------------
    def rc(self, node: int) -> Tuple[int, int]:
        return divmod(node, self.cfg.cols)

    def valid_ports(self, node: int) -> List[int]:
        r, c = self.rc(node)
        out = []
        if r > 0:
            out.append(PORT_N)
        if c < self.cfg.cols - 1:
            out.append(PORT_E)
        if r < self.cfg.rows - 1:
            out.append(PORT_S)
        if c > 0:
            out.append(PORT_W)
        return out

    # -- send-queue helpers ---------------------------------------------------
    def enqueue(self, node: int, typ: int, dst: int, osrc: int, tag: int) -> None:
        """S2: whole packets enter the FIFO packet queue or are dropped whole."""
        if len(self.sendq[node]) >= self.cfg.send_queue:
            self.stats["send_drop"] += 1
            return
        pkt = int(self.pkt_ctr[node]) & (self.cfg.pkt_wrap - 1)
        self.pkt_ctr[node] += 1
        self.sendq[node].append((typ, dst, osrc, tag, pkt, FLITS_OF[typ]))

    # -- cache helpers --------------------------------------------------------
    def _touch(self, lru, node, s, w):
        self.lru_clock[node] += 1
        lru[node, s, w] = self.lru_clock[node]

    def l1_probe(self, node: int, addr: int) -> Optional[Tuple[int, int]]:
        ca = self.cfg.cache
        tag = addr >> ca.l1_shift
        s = tag % ca.l1_sets
        for w in range(ca.l1_ways):
            if self.l1_tag[node, s, w] == tag:
                return s, w
        return None

    def l2_probe(self, node: int, tag2: int) -> Optional[Tuple[int, int]]:
        ca = self.cfg.cache
        s = tag2 % ca.l2_sets
        for w in range(ca.l2_ways):
            if self.l2_tag[node, s, w] == tag2:
                return s, w
        return None

    def install_l1(self, node: int, addr: int, owner: int) -> None:
        """S3: L1 install with victim write-back to the victim's L2 home."""
        ca = self.cfg.cache
        tag = addr >> ca.l1_shift
        s = tag % ca.l1_sets
        hit = self.l1_probe(node, addr)
        if hit is not None:
            self._touch(self.l1_lru, node, s, hit[1])
            self.l1_owner[node, s, hit[1]] = owner
            return
        # victim way: first invalid, else LRU (smallest lru, tie lowest way)
        way = -1
        for w in range(ca.l1_ways):
            if self.l1_tag[node, s, w] < 0:
                way = w
                break
        if way < 0:
            way = int(np.argmin(self.l1_lru[node, s]))
            # write back the victim (DESIGN §2: paper's mechanics)
            vtag1 = int(self.l1_tag[node, s, way])
            vowner = int(self.l1_owner[node, s, way])
            vtag2 = vtag1 >> (ca.l2_shift - ca.l1_shift)
            if vowner == node:
                if self.l2_probe(node, vtag2) is None:
                    self.stats["wb_miss"] += 1
            elif vowner >= 0:
                self.enqueue(node, MSG_WB, vowner, node, vtag2)
                self.stats["wb_sent"] += 1
            # vowner < 0: trap-filled block, written straight back to memory
        self.l1_tag[node, s, way] = tag
        self.l1_owner[node, s, way] = owner
        self._touch(self.l1_lru, node, s, way)

    def dir_set(self, node: int, tag2: int, owner: int) -> None:
        """S4: directory update — local apply or DU flit to the tag home."""
        home = self.cfg.dir_home(tag2)
        if home == node:
            self.stats["dir_update"] += 1
            if owner < 0:
                if self.dir_loc[tag2] == node:
                    self.dir_loc[tag2] = -1
            else:
                self.dir_loc[tag2] = owner
        else:
            self.enqueue(node, MSG_DU, home, owner, tag2)

    def install_l2(self, node: int, tag2: int) -> bool:
        """S5: L2 install; victim dir-entry delete; dir update for new tag."""
        ca = self.cfg.cache
        s = tag2 % ca.l2_sets
        if self.l2_probe(node, tag2) is not None:
            return True
        way = -1
        for w in range(ca.l2_ways):
            if self.l2_tag[node, s, w] < 0:
                way = w
                break
        if way < 0:
            best = None
            for w in range(ca.l2_ways):
                if self.l2_mig[node, s, w]:
                    continue
                k = (int(self.l2_lru[node, s, w]), w)
                if best is None or k < best[0]:
                    best = (k, w)
            if best is None:
                self.stats["l2_install_drop"] += 1
                return False
            way = best[1]
            vtag = int(self.l2_tag[node, s, way])
            self.dir_set(node, vtag, -1)   # delete victim's dir entry
        self.l2_tag[node, s, way] = tag2
        self.l2_mig[node, s, way] = 0
        self.l2_last_req[node, s, way] = -1
        self.l2_streak[node, s, way] = 0
        self._touch(self.l2_lru, node, s, way)
        self.dir_set(node, tag2, node)
        return True

    def fwd_lookup(self, node: int, tag2: int) -> int:
        for i in range(self.cfg.fwd_entries):
            if self.fwd_tag[node, i] == tag2:
                return int(self.fwd_dst[node, i])
        return -1

    def fwd_insert(self, node: int, tag2: int, dst: int) -> None:
        p = int(self.fwd_ptr[node]) % self.cfg.fwd_entries
        self.fwd_tag[node, p] = tag2
        self.fwd_dst[node, p] = dst
        self.fwd_ptr[node] = p + 1

    # -- phase 1a: inbound completions -----------------------------------------
    #: S14 — worst-case packets a handler may enqueue, by message type.
    NEED = {MSG_REQ: 2, MSG_REQ_FWD: 2, MSG_RA: 1, MSG_NACK: 0, MSG_DA: 1,
            MSG_DR: 1, MSG_DU: 0, MSG_WB: 0, MSG_B2: 3, MSG_MIG_ACK: 0}

    def q_space(self, node: int) -> int:
        return self.cfg.send_queue - len(self.sendq[node])

    def _exact_need(self, node: int, comp: Tuple[int, int, int, int]) -> int:
        """Exact number of packets the handler for ``comp`` will enqueue
        (the pc_depth > 1 drain-from-head gate; mirrors each handler's
        enqueue sites without mutating state)."""
        typ, src, osrc, tag = comp
        cfg = self.cfg
        if typ in (MSG_REQ, MSG_REQ_FWD):
            hit = self.l2_probe(node, tag)
            if hit is None:
                return 1                       # REQ_FWD or NACK
            s, w = hit
            trig = False
            if (cfg.migration_enabled and osrc != node
                    and not self.l2_mig[node, s, w]):
                streak = (self.l2_streak[node, s, w] + 1
                          if self.l2_last_req[node, s, w] == osrc else 1)
                trig = streak >= cfg.migrate_threshold
            return 1 + (1 if trig else 0)      # RA + maybe B2
        if typ == MSG_RA:
            if self.st[node] != ST_WAIT_DATA:
                return 0                       # stray
            # would install_l1 write back a remote-owned victim?
            ca = cfg.cache
            addr = int(self.pend_addr[node])
            t1 = addr >> ca.l1_shift
            s = t1 % ca.l1_sets
            if self.l1_probe(node, addr) is not None:
                return 0
            for w in range(ca.l1_ways):
                if self.l1_tag[node, s, w] < 0:
                    return 0                   # free way, no victim
            way = int(np.argmin(self.l1_lru[node, s]))
            vowner = int(self.l1_owner[node, s, way])
            return 1 if (vowner >= 0 and vowner != node) else 0
        if typ == MSG_DA:
            return 1                           # DR reply
        if typ == MSG_DR:
            return 1 if (self.st[node] == ST_WAIT_DIR and osrc >= 0) else 0
        if typ == MSG_B2:
            # MIG_ACK + one DU per remote directory update of install_l2
            ca = cfg.cache
            if self.l2_probe(node, tag) is not None:
                return 1
            s = tag % ca.l2_sets
            cnt = 1
            way = -1
            for w in range(ca.l2_ways):
                if self.l2_tag[node, s, w] < 0:
                    way = w
                    break
            if way < 0:
                best = None
                for w in range(ca.l2_ways):
                    if self.l2_mig[node, s, w]:
                        continue
                    k = (int(self.l2_lru[node, s, w]), w)
                    if best is None or k < best[0]:
                        best = (k, w)
                if best is None:
                    return 1                   # install fails: MIG_ACK only
                vtag = int(self.l2_tag[node, s, best[1]])
                if cfg.dir_home(vtag) != node:
                    cnt += 1
            if cfg.dir_home(tag) != node:
                cnt += 1
            return cnt
        return 0                               # NACK / DU / WB / MIG_ACK

    def phase1a(self, node: int) -> None:
        if not self.pending[node]:
            return
        comp = self.pending[node][0]   # FIFO: always serve the head
        # S14: backpressure — defer processing until the send queue can hold
        # the response; the completion queue head stays occupied, which
        # restricts further ejection at this node (see phase2).  pc_depth=1
        # gates on the worst-case NEED table (the paper's register
        # semantics, bit-identical to the seed); a deeper queue gates on
        # the exact response count so a head whose response actually fits
        # never blocks the drain (the ejection guarantee's second half).
        need = (self.NEED[comp[0]] if self.cfg.pc_depth == 1
                else self._exact_need(node, comp))
        if self.q_space(node) < need:
            # guaranteed drain (pc_depth > 1): a FULL queue must make
            # progress every cycle (its node cannot eject, so it may never
            # get to inject and free send-queue space on its own) — the
            # head fires anyway; responses that do not fit are dropped
            # whole (send_drop) and recovered by the req_timeout retry.
            if not (self.cfg.pc_depth > 1
                    and len(self.pending[node]) >= self.cfg.pc_depth):
                return
        self.pending[node].pop(0)
        typ, src, osrc, tag = comp
        cfg = self.cfg
        if typ in (MSG_REQ, MSG_REQ_FWD):
            self.stats["req_rcvd"] += 1
            hit = self.l2_probe(node, tag)
            if hit is not None:
                s, w = hit
                self._touch(self.l2_lru, node, s, w)
                self.enqueue(node, MSG_RA, osrc, osrc, tag)
                self.stats["reply_sent"] += 1
                if (cfg.migration_enabled and osrc != node
                        and not self.l2_mig[node, s, w]):
                    if self.l2_last_req[node, s, w] == osrc:
                        self.l2_streak[node, s, w] += 1
                    else:
                        self.l2_last_req[node, s, w] = osrc
                        self.l2_streak[node, s, w] = 1
                    if self.l2_streak[node, s, w] >= cfg.migrate_threshold:
                        self.l2_mig[node, s, w] = 1
                        self.enqueue(node, MSG_B2, osrc, node, tag)
                        self.stats["migrations"] += 1
            else:
                fwd = self.fwd_lookup(node, tag)
                if fwd >= 0 and fwd != node:
                    self.enqueue(node, MSG_REQ_FWD, fwd, osrc, tag)
                    self.stats["redirection"] += 1
                else:
                    self.enqueue(node, MSG_NACK, osrc, osrc, tag)
                    self.stats["trap"] += 1
        elif typ == MSG_RA:
            if self.st[node] == ST_WAIT_DATA:
                self.stats["reply_rcvd"] += 1
                self.install_l1(node, int(self.pend_addr[node]), src)
                self.st[node] = ST_IDLE
            else:
                self.stats["stray"] += 1
        elif typ == MSG_NACK:
            if self.st[node] == ST_WAIT_DATA:
                self.st[node] = ST_WAIT_MEM
                self.ctr[node] = cfg.mem_cycles
                self.install_mode[node] = INSTALL_L1_ONLY
                self.stats["mem_req"] += 1
            else:
                self.stats["stray"] += 1
        elif typ == MSG_DA:
            # S6: home reserves on miss so only one node ever memory-installs
            self.stats["dir_search"] += 1
            owner = int(self.dir_loc[tag])
            if owner < 0 or owner == osrc:
                self.dir_loc[tag] = osrc
                owner = -1
            self.enqueue(node, MSG_DR, osrc, owner, tag)
        elif typ == MSG_DR:
            owner = osrc   # payload
            if self.st[node] == ST_WAIT_DIR:
                if owner >= 0:
                    self.enqueue(node, MSG_REQ, owner, node, tag)
                    self.stats["req_made"] += 1
                    self.st[node] = ST_WAIT_DATA
                    if cfg.pc_depth > 1:   # arm the transaction timeout
                        self.ctr[node] = cfg.req_timeout
                else:
                    self.st[node] = ST_WAIT_MEM
                    self.ctr[node] = cfg.mem_cycles
                    self.install_mode[node] = INSTALL_L2
                    self.stats["mem_req"] += 1
            else:
                self.stats["stray"] += 1
        elif typ == MSG_DU:
            self.stats["dir_update"] += 1
            owner = osrc
            if owner < 0:
                if self.dir_loc[tag] == src:
                    self.dir_loc[tag] = -1
            else:
                self.dir_loc[tag] = owner
        elif typ == MSG_WB:
            self.stats["wb_rcvd"] += 1
            hit = self.l2_probe(node, tag)
            if hit is not None:
                self._touch(self.l2_lru, node, hit[0], hit[1])
            else:
                self.stats["wb_miss"] += 1
        elif typ == MSG_B2:
            self.stats["migrations_done"] += 1
            ok = self.install_l2(node, tag)
            # S13: MIG_ACK carries success (osrc=dest) or failure (osrc=-1);
            # on failure the source keeps the block and clears `migrating`.
            self.enqueue(node, MSG_MIG_ACK, src, node if ok else -1, tag)
        elif typ == MSG_MIG_ACK:
            hit = self.l2_probe(node, tag)
            if osrc >= 0:
                if hit is not None and self.l2_mig[node, hit[0], hit[1]]:
                    self.l2_tag[node, hit[0], hit[1]] = -1
                    self.l2_mig[node, hit[0], hit[1]] = 0
                self.fwd_insert(node, tag, osrc)
            else:
                if hit is not None:
                    self.l2_mig[node, hit[0], hit[1]] = 0
                    self.l2_streak[node, hit[0], hit[1]] = 0

    # -- phase 1b: trace-driven FSM --------------------------------------------
    def _consume_hit_under_miss(self, node: int) -> None:
        """S7: hit-under-miss — while waiting on a remote/memory miss the core
        keeps consuming trace addresses as long as they hit in L1."""
        p = int(self.tr_ptr[node])
        if p >= self.trace.shape[1] or self.trace[node, p] < 0:
            return
        addr = int(self.trace[node, p])
        hit = self.l1_probe(node, addr)
        if hit is not None:
            s = (addr >> self.cfg.cache.l1_shift) % self.cfg.cache.l1_sets
            self._touch(self.l1_lru, node, s, hit[1])
            self.stats["l1_hits"] += 1
            self.tr_ptr[node] = p + 1

    def phase1b(self, node: int) -> None:
        cfg = self.cfg
        ca = cfg.cache
        st = int(self.st[node])
        if st == ST_DONE:
            return
        if st == ST_IDLE:
            p = int(self.tr_ptr[node])
            if p >= self.trace.shape[1] or self.trace[node, p] < 0:
                self.st[node] = ST_DONE
                return
            addr = int(self.trace[node, p])
            self.tr_ptr[node] = p + 1
            hit = self.l1_probe(node, addr)
            if hit is not None:
                s = (addr >> ca.l1_shift) % ca.l1_sets
                self._touch(self.l1_lru, node, s, hit[1])
                self.stats["l1_hits"] += 1
                return
            self.stats["l1_misses"] += 1
            self.pend_addr[node] = addr
            self.st[node] = ST_L1_WAIT
            self.ctr[node] = cfg.l1_miss_cycles
            return
        if st == ST_L1_WAIT:
            self.ctr[node] -= 1
            if self.ctr[node] > 0:
                return
            if self.q_space(node) < 1:      # S14: hold until we can enqueue
                self.ctr[node] = 1
                return
            tag2 = int(self.pend_addr[node]) >> ca.l2_shift
            if self.l2_probe(node, tag2) is not None:
                self.stats["l2_local_hits"] += 1
                self.st[node] = ST_L2_WAIT
                self.ctr[node] = cfg.l2_hit_cycles
                return
            self.stats["l2_local_misses"] += 1
            home = cfg.dir_home(tag2)
            if home == node:
                # S8: inline directory access at the home node
                self.stats["dir_search"] += 1
                owner = int(self.dir_loc[tag2])
                if owner >= 0 and owner != node:
                    self.enqueue(node, MSG_REQ, owner, node, tag2)
                    self.stats["req_made"] += 1
                    self.st[node] = ST_WAIT_DATA
                    if cfg.pc_depth > 1:   # arm the transaction timeout
                        self.ctr[node] = cfg.req_timeout
                else:
                    self.dir_loc[tag2] = node   # reserve
                    self.st[node] = ST_WAIT_MEM
                    self.ctr[node] = cfg.mem_cycles
                    self.install_mode[node] = INSTALL_L2
                    self.stats["mem_req"] += 1
            else:
                self.enqueue(node, MSG_DA, home, node, tag2)
                self.st[node] = ST_WAIT_DIR
                if cfg.pc_depth > 1:   # arm the transaction timeout
                    self.ctr[node] = cfg.req_timeout
            return
        if st == ST_L2_WAIT:
            self.ctr[node] -= 1
            if self.ctr[node] > 0:
                return
            if self.q_space(node) < 1:      # S14
                self.ctr[node] = 1
                return
            s, w = self.l2_probe(node, int(self.pend_addr[node]) >> ca.l2_shift) or (-1, -1)
            if s >= 0:
                self._touch(self.l2_lru, node, s, w)
            self.install_l1(node, int(self.pend_addr[node]), node)
            self.st[node] = ST_IDLE
            return
        if st == ST_WAIT_MEM:
            self.ctr[node] -= 1
            if self.ctr[node] > 0:
                self._consume_hit_under_miss(node)
                return
            if self.q_space(node) < 3:      # S14 (DUv + DUn + WB worst case)
                self.ctr[node] = 1
                return
            addr = int(self.pend_addr[node])
            if self.install_mode[node] == INSTALL_L2:
                self.install_l2(node, addr >> ca.l2_shift)
                self.install_l1(node, addr, node)
            else:
                self.install_l1(node, addr, -1)
            self.st[node] = ST_IDLE
            return
        # ST_WAIT_DIR / ST_WAIT_DATA
        if cfg.pc_depth > 1:
            # transaction timeout: restart with a fresh DA to the tag's
            # home — retransmit-once recovery for responses the
            # guaranteed drain had to drop (stale duplicates -> `stray`)
            self.ctr[node] -= 1
            if self.ctr[node] <= 0:
                if self.q_space(node) < 1:      # S14: hold the retry
                    self.ctr[node] = 1
                else:
                    tag2 = int(self.pend_addr[node]) >> ca.l2_shift
                    self.enqueue(node, MSG_DA, cfg.dir_home(tag2), node, tag2)
                    self.st[node] = ST_WAIT_DIR
                    self.ctr[node] = cfg.req_timeout
        self._consume_hit_under_miss(node)

    # -- phase 2: arbitration ---------------------------------------------------
    def _prefs(self, node: int, flit: Flit) -> List[int]:
        """S9: PMDR preference list — desired X, desired Y, then remaining
        valid ports in index order."""
        r, c = self.rc(node)
        dr_, dc_ = divmod(flit.dst, self.cfg.cols)
        prefs: List[int] = []
        if dc_ > c:
            prefs.append(PORT_E)
        elif dc_ < c:
            prefs.append(PORT_W)
        if dr_ > r:
            prefs.append(PORT_S)
        elif dr_ < r:
            prefs.append(PORT_N)
        vp = self.valid_ports(node)
        prefs = [p for p in prefs if p in vp]
        for p in vp:
            if p not in prefs:
                prefs.append(p)
        return prefs

    def rob_can_accept(self, node: int, flit: Flit) -> bool:
        """S10: eject only if the reorder buffer can take the flit."""
        if flit.nfl == 1:
            return True   # single-flit packets complete via the pending register
        for slot in self.rob[node]:
            if slot[0] == flit.src and slot[1] == flit.pkt:
                return True
        return len(self.rob[node]) < self.cfg.rob_slots

    def phase2(self, node: int):
        """Returns (out_ports: dict port->flit, eject: Optional[Flit],
        injected: bool, deflect_flags: dict id(flit)->bool)."""
        flits = [(p, f) for p, f in enumerate(self.inp[node]) if f is not None]
        vp = self.valid_ports(node)

        # S11: ejection — oldest (age desc, port asc) flit destined here that
        # the ROB can accept; at most one per cycle.  S14 + ejection
        # guarantee (pc_depth > 1): with an empty pending-completion queue
        # any deliverable flit may eject (the paper's behaviour); once the
        # queue is occupied only flits aged past cfg.eject_age_threshold
        # eject — into spare queue capacity while a slot is free, and into
        # a free ROB slot (buffered ejection; the completion parks and is
        # promoted as the queue drains, see phase3) when the queue is full.
        # pc_depth=1 keeps the paper's exact single-register bar.
        eject: Optional[Tuple[int, Flit]] = None
        pcq = self.pending[node]
        depth = self.cfg.pc_depth

        def ej_allowed(f: Flit) -> bool:
            if not pcq:
                return self.rob_can_accept(node, f)
            if depth == 1 or f.age < self.cfg.eject_age_threshold:
                return False
            if len(pcq) < depth:
                return self.rob_can_accept(node, f)
            # queue full — parking path: a single-flit completion needs a
            # fresh ROB slot; a multi-flit flit parks in its own slot
            if f.nfl == 1:
                return len(self.rob[node]) < self.cfg.rob_slots
            return self.rob_can_accept(node, f)

        for p, f in sorted(flits, key=lambda pf: (-pf[1].age, pf[0])):
            if f.dst == node and ej_allowed(f):
                eject = (p, f)
                break
        remaining = [(p, f) for p, f in flits if eject is None or p != eject[0]]

        # S12: injection — head of the send queue joins arbitration iff the
        # number of remaining network flits is below the number of valid
        # ports; the injected flit has age 0 and loses all ties (slot 4).
        inj: Optional[Flit] = None
        if self.sendq[node] and len(remaining) < len(vp):
            typ, dst, osrc, tag, pkt, nfl = self.sendq[node][0]
            inj = Flit(0, node, dst, osrc, typ, tag, pkt, int(self.q_fid[node]), nfl)

        cands = [(p, f) for p, f in remaining]
        if inj is not None:
            cands.append((4, inj))
        order = sorted(cands, key=lambda pf: (-pf[1].age, pf[0]))

        taken: set = set()
        out: Dict[int, Flit] = {}
        deflected: Dict[int, bool] = {}
        for p, f in order:
            prefs = self._prefs(node, f)
            wanted_eject = (f.dst == node)
            assigned = None
            for q in prefs:
                if q not in taken:
                    assigned = q
                    break
            assert assigned is not None, "bufferless invariant violated"
            taken.add(assigned)
            out[assigned] = f
            deflected[id(f)] = wanted_eject or (assigned != prefs[0])
        injected = inj is not None
        if injected:
            self.q_fid[node] += 1
            if self.q_fid[node] == inj.nfl:
                self.sendq[node].pop(0)
                self.q_fid[node] = 0
            self.stats["injected"] += 1
        return out, eject, deflected

    # -- phase 3: transfer --------------------------------------------------
    def phase3(self, all_out, all_eject, all_defl) -> None:
        cfg = self.cfg
        n = cfg.num_nodes
        new_inp: List[List[Optional[Flit]]] = [[None] * NUM_PORTS for _ in range(n)]
        for node in range(n):
            r, c = self.rc(node)
            for port, f in all_out[node].items():
                if all_defl[node].get(id(f), False):
                    f.age += 1
                    self.stats["deflections"] += 1
                self.stats["hops"] += 1
                if port == PORT_N:
                    nb, back = (r - 1) * cfg.cols + c, PORT_S
                elif port == PORT_S:
                    nb, back = (r + 1) * cfg.cols + c, PORT_N
                elif port == PORT_E:
                    nb, back = r * cfg.cols + (c + 1), PORT_W
                else:
                    nb, back = r * cfg.cols + (c - 1), PORT_E
                new_inp[nb][back] = f
        self.inp = new_inp
        depth = self.cfg.pc_depth
        for node in range(n):
            pcq = self.pending[node]
            # promotion: the parked completion (count reached its flit
            # total while the queue was full) with the smallest (src, pkt)
            # enters the queue tail — same rule as the vectorized deliver
            parked = [s for s in self.rob[node] if s[6] >= s[5]]
            if parked and len(pcq) < depth:
                sl = min(parked, key=lambda s: (s[0], s[1]))
                pcq.append((sl[2], sl[0], sl[4], sl[3]))
                self.rob[node].remove(sl)
            ej = all_eject[node]
            if ej is None:
                continue
            f = ej[1]
            self.stats["flits_delivered"] += 1
            if f.nfl == 1:
                if len(pcq) < depth:
                    pcq.append((f.typ, f.src, f.osrc, f.tag))
                else:   # park (phase2 guaranteed a free slot)
                    assert len(self.rob[node]) < self.cfg.rob_slots
                    self.rob[node].append(
                        [f.src, f.pkt, f.typ, f.tag, f.osrc, 1, 1])
                continue
            slot = None
            for s in self.rob[node]:
                if s[0] == f.src and s[1] == f.pkt:
                    slot = s
                    break
            if slot is None:
                slot = [f.src, f.pkt, f.typ, f.tag, f.osrc, f.nfl, 0]
                self.rob[node].append(slot)
            slot[6] += 1
            if slot[6] == slot[5]:
                if len(pcq) < depth:
                    pcq.append((slot[2], slot[0], slot[4], slot[3]))
                    self.rob[node].remove(slot)
                # else: the completed slot stays parked (count == total)

    # -- driver ----------------------------------------------------------------
    def network_empty(self) -> bool:
        if any(f is not None for ports in self.inp for f in ports):
            return False
        if any(self.sendq[n] for n in range(self.cfg.num_nodes)):
            return False
        if any(self.rob[n] for n in range(self.cfg.num_nodes)):
            return False
        if any(self.pending):
            return False
        return True

    def finished(self) -> bool:
        return bool(np.all(self.st == ST_DONE)) and self.network_empty()

    def step(self) -> None:
        n = self.cfg.num_nodes
        for node in range(n):
            self.phase1a(node)
        for node in range(n):
            self.phase1b(node)
        all_out, all_eject, all_defl = {}, {}, {}
        for node in range(n):
            out, eject, defl = self.phase2(node)
            all_out[node], all_eject[node], all_defl[node] = out, eject, defl
        self.phase3(all_out, all_eject, all_defl)
        self.cycle += 1

    def run(self, max_cycles: Optional[int] = None) -> Dict[str, int]:
        """Drive to completion, with the same livelock / directory-
        saturation monitors as the vectorized driver (`sim._run_jit`) —
        the golden-model equivalence contract covers pathological inputs
        too, so both sides must abort at the same cycle with the same
        snapshot (the stats ARE the snapshot: they were frozen / sampled
        at the fire cycle)."""
        limit = max_cycles or self.cfg.max_cycles
        n = self.cfg.num_nodes
        lw = self.cfg.livelock_window_effective
        sw = self.cfg.sat_window if n >= 256 else 0
        central = self.cfg.centralized_directory

        def prog():
            return tuple(v for k, v in self.stats.items()
                         if k not in ("hops", "deflections"))

        prev, frz = prog(), 0
        refs_anchor = int(self.tr_ptr.sum())
        abort = ""
        while not self.finished() and self.cycle < limit:
            self.step()
            cur = prog()
            frz = frz + 1 if cur == prev else 0
            prev = cur
            fin = self.finished()
            fire_sat = False
            if sw and self.cycle % sw == 0:
                refs = int(self.tr_ptr.sum())
                wd = int((self.st == ST_WAIT_DIR).sum())
                wdd = int((self.st == ST_WAIT_DATA).sum())
                fire_sat = (not fin and central and (wd + wdd) * 2 >= n
                            and (refs - refs_anchor) * 2 < n)
                refs_anchor = refs
            if fire_sat:
                abort = "dir_saturation"
                break
            if lw and frz >= lw and not fin:
                abort = "livelock"
                break
        out = dict(self.stats)
        out["cycles"] = self.cycle
        if abort:
            out["finished"] = 0
            out["aborted"] = abort
            flits = [f for ports in self.inp for f in ports if f is not None]
            out["circulating_flits"] = len(flits)
            out["wait_dir_nodes"] = int((self.st == ST_WAIT_DIR).sum())
            out["wait_data_nodes"] = int((self.st == ST_WAIT_DATA).sum())
            out["stalled_queues"] = sum(1 for q in self.sendq if q)
            out["flits_to_node0"] = sum(1 for f in flits if f.dst == 0)
        else:
            out["finished"] = int(self.finished())
        return out
