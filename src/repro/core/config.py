"""Configuration for the bufferless-NoC LCMP simulator.

Semantics are shared verbatim by the serial golden model
(:mod:`repro.core.ref_serial`) and the vectorized JAX simulator
(:mod:`repro.core.sim`): this module is the single source of truth for
message types, packet lengths (paper Table 1) and latency/geometry knobs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["CacheConfig", "SimConfig", "paper_small", "paper_large_cache",
           "APP_NAMES", "FLITS_OF", "NUM_MSG_TYPES"]

# ---------------------------------------------------------------------------
# Message types (paper Table 1 + control messages implied by §3.3/§3.4).
# Values are stable: they appear inside int8/int32 device arrays.
# ---------------------------------------------------------------------------
MSG_REQ = 0       # remote L2 read request                        (1 flit)
MSG_RA = 1        # data reply carrying one L1 block              (4 flits)
MSG_NACK = 2      # trap reply: block not found at owner          (1 flit)
MSG_DA = 3        # directory lookup request                      (1 flit)
MSG_DR = 4        # directory reply (payload: owner or -1)        (1 flit)
MSG_DU = 5        # directory update (payload: owner or -1=del)   (1 flit)
MSG_WB = 6        # L1 victim write-back to its L2 home           (4 flits)
MSG_B2 = 7        # L2 block migration / replacement transfer     (16 flits)
MSG_MIG_ACK = 8   # migration installed at destination            (1 flit)
MSG_REQ_FWD = 9   # redirected request (paper's RR)               (1 flit)

NUM_MSG_TYPES = 10

#: packet length in flits, indexed by message type (paper Table 1: DA=1,
#: DR=1, RR=1, RA=4, B2=16; WB carries an L1 block like RA).
FLITS_OF = (1, 4, 1, 1, 1, 1, 4, 16, 1, 1)

# FSM states of a core (phase 1).
ST_IDLE = 0       # ready to consume the next trace address
ST_L1_WAIT = 1    # counting down the L1 miss penalty
ST_L2_WAIT = 2    # counting down the local-L2 hit latency
ST_WAIT_DIR = 3   # DA sent, waiting for DR
ST_WAIT_DATA = 4  # REQ sent to owner, waiting for RA / NACK
ST_WAIT_MEM = 5   # counting down the off-chip memory latency
ST_DONE = 6       # trace exhausted (keeps routing + serving remote requests)

# Port indices. The "directions" of a 2-D mesh router; EJECT/INJECT are
# virtual ports used only during arbitration.
PORT_N, PORT_E, PORT_S, PORT_W = 0, 1, 2, 3
NUM_PORTS = 4
EJECT = 4
INJECT_SLOT = 4   # index of the injection candidate in the arbitration list

# Memory-install targets (trap path installs to L1 only — DESIGN.md §2).
INSTALL_L2 = 0
INSTALL_L1_ONLY = 1


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """Geometry of the per-node caches (paper Table 4 rows)."""

    l1_sets: int = 32
    l1_ways: int = 2
    l1_block: int = 32          # bytes (paper: 32B L1 lines)
    l2_sets: int = 32
    l2_ways: int = 2
    l2_block: int = 64          # bytes (paper: 64B L2 lines)

    @property
    def l1_shift(self) -> int:
        return self.l1_block.bit_length() - 1

    @property
    def l2_shift(self) -> int:
        return self.l2_block.bit_length() - 1


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """Full simulator configuration.

    ``rows`` × ``cols`` is the simulated mesh; every other field mirrors a
    knob of the paper's simulator (§3, §6).
    """

    rows: int = 8
    cols: int = 8
    cache: CacheConfig = dataclasses.field(default_factory=CacheConfig)

    # Latencies (cycles).
    l1_miss_cycles: int = 2     # paper §7.1.1 "wait up to L1 miss cycle"
    l2_hit_cycles: int = 4
    mem_cycles: int = 80        # off-chip fetch (no flits routed — DESIGN §2)

    # Address space of the simulated machine. Directory ("location array",
    # paper §6.2.2) has 2**addr_bits / l2_block entries.
    addr_bits: int = 20

    # LSPD management.
    migration_enabled: bool = True
    migrate_threshold: int = 3  # consecutive remote hits by the same node
    fwd_entries: int = 4        # per-node forwarding table (redirection)
    centralized_directory: bool = True   # paper default; False = tag-home

    # Node plumbing.
    rob_slots: int = 8          # reorder-buffer packet slots per node
    send_queue: int = 64        # outbound flit-queue depth per node
    max_cycles: int = 200_000

    # Pending-completion queue (ejection guarantee — docs/architecture.md).
    # The paper's S14 uses a single pending-completion register that bars
    # ejection while occupied; combined with S14 handler backpressure this
    # can livelock whole (cfg, trace) combos (the ROADMAP 16x16/matmul
    # wedge).  pc_depth > 1 turns the register into a small FIFO queue:
    # an *occupied* (but not full) queue no longer bars ejection, and the
    # phase-1a handler drains from the queue head.  pc_depth=1 is the
    # compatibility escape hatch — bit-identical to the single-register
    # semantics.  (Structural: changes state shapes / compiled programs.)
    pc_depth: int = 4
    # Guaranteed-ejection age threshold: with a non-empty (but not full)
    # completion queue, only flits that have deflected at least this many
    # times are ejected into the spare capacity; younger flits still see
    # the paper-faithful ejection bar.  0 = always eject while a slot is
    # free.  (Traced per-scenario knob — rides as SimState.knob_ej_age.)
    # Default 0 measured by benchmarks/zoo_tune.py across the pattern/
    # hotspot/rates/wedge zoo (benchmarks/zoo_thresholds.json): every
    # (age, timeout) grid point completes every scenario, and the
    # ungated setting is uniformly fastest (1.3% mean cycles over the
    # previous age-8 default, 10x fewer recovered drops).
    eject_age_threshold: int = 0
    # Transaction timeout (pc_depth > 1 only): a node stuck in
    # WAIT_DIR/WAIT_DATA for this many cycles restarts its transaction
    # with a fresh DA to the tag's directory home.  This is the
    # retransmit-once-style recovery that makes the guaranteed drain
    # safe: a response the saturated handler had to drop (send_drop) is
    # simply re-requested, and stale duplicates fall into the existing
    # `stray` accounting.  Static (compiled constant), not a traced knob.
    req_timeout: int = 256

    # Progress monitors (driver-level, repro.core.sim).  They never alter
    # the cycle-by-cycle semantics of a healthy run — they only stop a run
    # early with a diagnostic instead of burning the whole cycle budget.
    #
    # Livelock: abort when no *progress* statistic (anything but the
    # pure-motion counters hops/deflections) changes for this many
    # consecutive cycles while the run is unfinished.  None = auto
    # (max(512, 4*mem_cycles) — comfortably above the longest legitimate
    # quiet period, a machine-wide off-chip memory stall); 0 disables.
    livelock_window: Optional[int] = None
    # Directory saturation (the paper's node-0 hotspot): evaluated every
    # sat_window cycles on centralized-directory runs at >= 256 nodes;
    # fires when at least half the nodes sit in WAIT_DIR/WAIT_DATA while
    # fewer than num_nodes/2 references retired over the window.
    # 0 disables.
    sat_window: int = 1024

    # Simulator implementation knobs (do not change semantics).
    flit_dtype: str = "int32"
    dir_layout: str = "flat"   # "flat" | "home" (home = sharded with nodes)
    use_pallas_router: bool = False   # Phase-2 arbitration via Pallas kernel
    # Storage layout of SimState (repro.core.state.leaf_dtypes):
    #   "wide"   — every leaf is int32 (the historical layout).
    #   "packed" — each leaf gets the smallest of int8/int16/int32 that
    #              holds its validated value bounds (FSM states and flags
    #              in int8, tags/ids/addresses in int16 where addr_bits /
    #              num_nodes / max_cycles permit).  All phases still
    #              compute in int32 — state is widened on load and
    #              narrowed on store at the cycle boundary — so semantics
    #              and serial golden-model bit-parity are unchanged.
    #              Packet ids then wrap at 2**14 instead of 2**30
    #              (mirrored in the serial model), which is aliasing-free
    #              while in-flight packets per source stay below 16384.
    #              (Structural: changes buffer dtypes / compiled programs.)
    state_dtype_policy: str = "wide"

    @property
    def num_nodes(self) -> int:
        return self.rows * self.cols

    @property
    def livelock_window_effective(self) -> int:
        if self.livelock_window is None:
            return max(512, 4 * self.mem_cycles)
        return self.livelock_window

    @property
    def dir_entries(self) -> int:
        return (1 << self.addr_bits) >> self.cache.l2_shift

    @property
    def pkt_wrap(self) -> int:
        """Modulus of the per-source packet-id counter.  The wide layout
        keeps the historical 2**30; the packed layout wraps at 2**14 so
        packet ids fit int16 state (unique while in-flight packets per
        source stay below 16384 — far beyond ROB/queue capacity)."""
        return (1 << 14) if self.state_dtype_policy == "packed" else (1 << 30)

    def dir_home(self, tag: int) -> int:
        """Node id owning the directory entry for ``tag``."""
        if self.centralized_directory:
            return 0
        return tag % self.num_nodes

    def validate(self) -> None:
        assert self.rows >= 2 and self.cols >= 2, "mesh must be at least 2x2"
        assert self.cache.l2_block % self.cache.l1_block == 0
        assert self.migrate_threshold >= 1
        assert self.rob_slots >= 2
        assert self.pc_depth >= 1, "pending-completion queue needs >= 1 slot"
        assert self.eject_age_threshold >= 0
        assert self.req_timeout >= 1
        if self.state_dtype_policy not in ("wide", "packed"):
            raise ValueError(
                f"state_dtype_policy must be 'wide' or 'packed', got "
                f"{self.state_dtype_policy!r}")
        if self.state_dtype_policy == "packed":
            # l2_streak is stored int16 with a saturating narrow at 32767;
            # every threshold comparison is then exact iff the threshold
            # itself stays below the saturation point.
            if self.migrate_threshold > 32766:
                raise ValueError(
                    "packed state layout stores migration streaks in int16 "
                    f"(saturating at 32767); migrate_threshold="
                    f"{self.migrate_threshold} would make threshold "
                    "comparisons inexact — use the wide layout")
            if self.addr_bits > 30:
                raise ValueError(
                    "packed state layout needs addresses (and their "
                    f"packet-id headroom) inside int32; addr_bits="
                    f"{self.addr_bits} > 30")


# Paper presets -------------------------------------------------------------

def paper_small() -> SimConfig:
    """Table 4 row 3/4 cache geometry (32,2,32 / 32,2,32)."""
    return SimConfig(cache=CacheConfig(32, 2, 32, 32, 2, 32 * 2))


def paper_large_cache() -> SimConfig:
    """Table 4 row 1 geometry (L1 128,4,32; L2 512,8,64)."""
    return SimConfig(cache=CacheConfig(128, 4, 32, 512, 8, 64))


APP_NAMES = ("matmul", "apsi", "mgrid", "wupwise", "equake")
