"""Public surface of the bufferless-NoC simulation core.

The stable API users script against::

    from repro.core import SimConfig, run, compile_plan, execute_plan

    cfg = SimConfig(rows=8, cols=8, centralized_directory=False)
    stats = run(cfg, resolve_trace(cfg, "matmul", 50, seed=0))

Symbols resolve lazily (PEP 562): importing :mod:`repro.core` pulls in
*nothing* heavy, so ``engine.expose_host_devices()`` — which must run
before the first jax import to widen the host device list — keeps
working when called after ``from repro.core import engine``.  The
attribute access itself triggers the real submodule import.

Everything here is covered by the doc-coverage gate
(``scripts/check_doc_coverage.py``); the deeper per-module surfaces
(:mod:`repro.core.engine`, :mod:`repro.core.sweep`, ...) remain public
too — this module is the curated front door, not a fence.
"""
from __future__ import annotations

import importlib

#: public name -> (defining module, attribute) — the lazy export table
_EXPORTS = {
    # configuration + solo runs
    "SimConfig": ("repro.core.config", "SimConfig"),
    "CacheConfig": ("repro.core.config", "CacheConfig"),
    "run": ("repro.core.sim", "run"),
    "stats_list": ("repro.core.sim", "stats_list"),
    "aggregate_stats": ("repro.core.sim", "aggregate_stats"),
    "network_health": ("repro.core.sim", "network_health"),
    "STAT_NAMES": ("repro.core.ref_serial", "STAT_NAMES"),
    # execution-plan layer
    "Scenario": ("repro.core.engine", "Scenario"),
    "make_scenario": ("repro.core.engine", "make_scenario"),
    "compile_plan": ("repro.core.engine", "compile_plan"),
    "execute_plan": ("repro.core.engine", "execute_plan"),
    "load_manifest": ("repro.core.engine", "load_manifest"),
    # workload registry
    "register": ("repro.core.workloads", "register"),
    "parse_source": ("repro.core.workloads", "parse_source"),
    "resolve_trace": ("repro.core.workloads", "resolve_trace"),
    # scenario zoo
    "expand_zoo": ("repro.core.zoo", "expand_zoo"),
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        modname, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module 'repro.core' has no attribute {name!r}") from None
    return getattr(importlib.import_module(modname), attr)


def __dir__():
    return sorted(set(globals()) | set(__all__))
