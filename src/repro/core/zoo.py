"""Scenario-zoo registry: named scenario families for tuning and CI.

A :class:`ZooFamily` is a compact cross-product — mesh sizes x workload
sources x seeds x refs, over a shared set of ``SimConfig`` overrides —
that expands into plan-engine scenarios (:func:`ZooFamily.expand`) or a
JSON manifest (:func:`ZooFamily.manifest`).  Families are the "broader
scenario zoo" the ROADMAP threshold-tuning residual calls for: the
ejection-guarantee knobs (``eject_age_threshold`` / ``req_timeout``)
were tuned on one wedge family only; ``benchmarks/zoo_tune.py`` sweeps
them across any set of families registered here.

Sources are workload-registry specs (:mod:`repro.core.workloads`), so
every synthetic pattern (with parameters) and the ``loop:`` reference
generators are zoo-able.  Pattern families set
``centralized_directory=False`` — synthetic destination patterns
materialize through distributed directory homes (see
:mod:`repro.core.workloads.patterns`).

Zoo spec grammar (the launcher's ``--zoo`` and ``zoo_tune.py``)::

    patterns-small                         # a family, as registered
    patterns-small:refs=20,seeds=0+1+2     # with overrides
    patterns-small:meshes=4x4+8x8          # '+'-joined list values
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

from .config import SimConfig
from .engine import Scenario, make_scenario
from .workloads import PATTERN_NAMES, TRACE_APPS, valid_source

__all__ = ["ZooFamily", "register_family", "get_family", "family_names",
           "zoo_summary", "expand_zoo"]


@dataclasses.dataclass(frozen=True)
class ZooFamily:
    """One named scenario family: a mesh x source x seed cross-product.

    Attributes:
        name: registry key (the ``--zoo`` spelling).
        help: one-line description for CLI listings.
        meshes: ``(rows, cols)`` mesh shapes to cross.
        sources: workload-registry source specs to cross.
        seeds: trace-synthesis seeds to cross.
        refs: references per core for every scenario.
        base: ``SimConfig`` field overrides shared by the family
            (e.g. ``centralized_directory=False`` for pattern families).
    """

    name: str
    help: str
    meshes: Tuple[Tuple[int, int], ...]
    sources: Tuple[str, ...]
    seeds: Tuple[int, ...] = (0,)
    refs: int = 60
    base: Mapping[str, object] = dataclasses.field(default_factory=dict)

    @property
    def size(self) -> int:
        """Scenarios this family expands to."""
        return len(self.meshes) * len(self.sources) * len(self.seeds)

    def expand(self, base: Optional[SimConfig] = None) -> List[Scenario]:
        """The family's scenario list (mesh-major, then source, then
        seed), built over ``base`` (default :class:`SimConfig`) with the
        family's overrides applied."""
        cfg = base or SimConfig()
        return [make_scenario(cfg, r, c, app=src, seed=s,
                              refs_per_core=self.refs, **dict(self.base))
                for (r, c) in self.meshes
                for src in self.sources
                for s in self.seeds]

    def manifest(self) -> Dict:
        """The family as a ``load_manifest``-compatible JSON object."""
        return {
            "base": dict(self.base),
            "scenarios": [
                {"rows": r, "cols": c, "app": src, "seed": s,
                 "refs_per_core": self.refs}
                for (r, c) in self.meshes
                for src in self.sources
                for s in self.seeds],
        }


_ZOO: Dict[str, ZooFamily] = {}


def register_family(fam: ZooFamily) -> ZooFamily:
    """Add ``fam`` to the zoo (name must be new; every source spec must
    parse against the workload registry) and return it."""
    if fam.name in _ZOO:
        raise ValueError(f"zoo family {fam.name!r} already registered")
    bad = [s for s in fam.sources if not valid_source(s)]
    if bad:
        raise ValueError(f"zoo family {fam.name!r}: invalid source "
                         f"spec(s) {bad}")
    _ZOO[fam.name] = fam
    return fam


def family_names() -> Tuple[str, ...]:
    """Registered family names (registration order)."""
    return tuple(_ZOO)


def get_family(name: str) -> ZooFamily:
    """Look up a family; ``ValueError`` listing the zoo on a miss."""
    try:
        return _ZOO[name]
    except KeyError:
        raise ValueError(f"unknown zoo family {name!r}; families: "
                         f"{list(_ZOO)}") from None


def zoo_summary() -> str:
    """One line per family: name, size, description (CLI listing)."""
    return "\n".join(f"{f.name} ({f.size} scenarios): {f.help}"
                     for f in _ZOO.values())


def _parse_meshes(raw: str) -> Tuple[Tuple[int, int], ...]:
    out = []
    for item in raw.split("+"):
        r, _, c = item.lower().partition("x")
        out.append((int(r), int(c)))
    return tuple(out)


def expand_zoo(spec: str, base: Optional[SimConfig] = None
               ) -> List[Scenario]:
    """Expand a zoo spec (``family`` or ``family:key=val,...``) into
    scenarios over ``base``.

    Overridable keys: ``refs`` (int), ``seeds`` (``+``-joined ints),
    ``meshes`` (``+``-joined ``RxC``), ``sources`` (``+``-joined source
    specs — which may themselves contain ``:``/``,``-free forms only;
    use a manifest for parameterized sources beyond the family's own)."""
    name, _, argstr = spec.partition(":")
    fam = get_family(name.strip())
    kw: Dict[str, object] = {}
    for tok in argstr.split(","):
        tok = tok.strip()
        if not tok:
            continue
        key, eq, raw = tok.partition("=")
        key, raw = key.strip(), raw.strip()
        if not eq or key not in ("refs", "seeds", "meshes", "sources"):
            raise ValueError(
                f"zoo spec {spec!r}: expected key=val with key in "
                "['refs', 'seeds', 'meshes', 'sources'], got " + repr(tok))
        if key == "refs":
            kw["refs"] = int(raw)
        elif key == "seeds":
            kw["seeds"] = tuple(int(x) for x in raw.split("+"))
        elif key == "meshes":
            kw["meshes"] = _parse_meshes(raw)
        else:
            kw["sources"] = tuple(raw.split("+"))
    if kw:
        fam = dataclasses.replace(fam, **kw)
        bad = [s for s in fam.sources if not valid_source(s)]
        if bad:
            raise ValueError(f"zoo spec {spec!r}: invalid source(s) {bad}")
    return fam.expand(base)


# ---------------------------------------------------------------------------
# Built-in families.
# ---------------------------------------------------------------------------

#: distributed directory: destination patterns materialize through the
#: tag-home map (centralized would collapse every pattern onto node 0)
_DIST = {"centralized_directory": False}

register_family(ZooFamily(
    name="patterns-tiny",
    help="all five synthetic patterns on a 4x4 mesh, 2 seeds — the CI "
         "zoo-smoke slice",
    meshes=((4, 4),), sources=PATTERN_NAMES, seeds=(0, 1), refs=12,
    base=_DIST))

register_family(ZooFamily(
    name="patterns-small",
    help="all five synthetic patterns at full injection rate on 4x4 and "
         "8x8 meshes",
    meshes=((4, 4), (8, 8)), sources=PATTERN_NAMES, seeds=(0, 1), refs=40,
    base=_DIST))

register_family(ZooFamily(
    name="patterns-rates",
    help="each pattern at injection rates 0.33 / 0.66 / 1.0 on 8x8",
    meshes=((8, 8),),
    sources=tuple(f"{p}:rate={r}" for p in PATTERN_NAMES
                  for r in ("0.33", "0.66", "1.0")),
    seeds=(0,), refs=60, base=_DIST))

register_family(ZooFamily(
    name="hotspot-stress",
    help="hotspot concentration sweep (frac 0.25..1.0, 1 and 2 hot "
         "nodes) on 8x8 — the ejection-guarantee stressor",
    meshes=((8, 8),),
    sources=tuple(f"hotspot:frac={f},hot={h}"
                  for f in ("0.25", "0.5", "0.75", "1.0") for h in (1, 2)),
    seeds=(0,), refs=60, base=_DIST))

register_family(ZooFamily(
    name="apps-small",
    help="the paper's five application models plus the uniform injector "
         "on 8x8",
    meshes=((8, 8),), sources=tuple(TRACE_APPS) + ("random",),
    seeds=(0, 1), refs=60, base=_DIST))

register_family(ZooFamily(
    name="wedge",
    help="the former S14 ejection-bar livelock family (16x16 loop:matmul, "
         "ROADMAP) — the original threshold-tuning anchor",
    meshes=((16, 16),), sources=("loop:matmul",), seeds=(0,), refs=20,
    base=_DIST))
