"""Multi-device NoC simulation: spatial and composed ``shard_map`` backends.

Two decompositions live here, sharing one step builder:

* **Spatial (2-D)** — the simulated router grid ``(R, C)`` is
  block-partitioned over the device mesh: rows over ``row_axes`` (e.g.
  ``("pod", "data")``), columns over ``col_axes`` (e.g. ``("model",)``).
  Every phase is node-local except the phase-3 flit transfer, whose
  cross-tile edges become four ``ppermute`` halo slabs per cycle — the
  simulated 2-D mesh maps onto the physical 2-D ICI torus, so halo traffic
  is near-neighbour on the real interconnect.

* **Composed (3-D)** — a *batch* of B scenarios of the same mesh shape is
  laid out over a ``(scenario, rows, cols)`` device mesh: the scenario
  axis is sharded over ``batch_axes`` and, within each spatial tile, the
  local scenarios are vmapped through the very same per-tile cycle step.
  Halo exchange is unchanged per tile — the batched halo slabs ride the
  same four ``ppermute`` collectives (one per direction, all local
  scenarios batched into each), so the fixed collective cost is paid once
  per cycle, not once per scenario.  Termination is per scenario: a
  finished scenario freezes bit-identically to its solo run while its
  batch-mates keep stepping.  :func:`run_composed` is the driver.

The directory must be distributed (``dir_layout="home"``): entry(tag)
lives at node ``tag % N`` which is the only node that ever touches it, so
the location array shards exactly like the nodes and directory traffic
rides the simulated network itself (no extra collectives).
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .cache import phase1a, phase1b
from .config import SimConfig
from .noc import deliver, phase2
from .sim import (ABORT_LIVELOCK, ExecAux, _PROG_IDX, check_cycle_cap,
                  diag_counts, finished as _finished, stats_list)
from .state import (NUM_F, NodeCtx, SimState, fold_stats, init_state,
                    leaf_dtypes, make_geometry, narrow_state, widen_state)

__all__ = ["ShardedSim", "run_composed", "make_sharded_step", "to_grid",
           "state_specs", "make_geo_arrays"]

# jax >= 0.5 exports shard_map at the top level; 0.4.x keeps it in
# experimental.  The replication-check kwarg was also renamed
# (check_rep -> check_vma); stats leave the tile replicated but become
# device-varying inside the scan (re-replicated via psum), so the check
# must be off either way.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map
_SM_NOCHECK = {
    ("check_vma" if "check_vma" in inspect.signature(_shard_map).parameters
     else "check_rep"): False}

I32 = jnp.int32

#: leaves whose leading dims are (scenario?, node) — the node dim is
#: reshaped (N, …) -> (R, C, …) for sharding; everything else (stats,
#: cycle, knob_*) is per-scenario scalar state, replicated across the
#: spatial tiles and sharded only over the scenario axis (if any).
_NODE_LEAVES = {
    "st", "ctr", "tr_ptr", "pend_addr", "install_mode", "pkt_ctr",
    "lru_clock", "l1_tag", "l1_lru", "l1_owner", "l2_tag", "l2_lru",
    "l2_mig", "l2_last", "l2_streak", "dir_loc", "fwd_tag", "fwd_dst",
    "fwd_ptr", "inp", "q_desc", "q_head", "q_size", "q_fid", "rob", "pc",
    "trace",
}


def to_grid(s: SimState, cfg: SimConfig) -> SimState:
    """Reshape node-major leaves ``(N, …) -> (R, C, …)``.

    A batched state (leading scenario axis, detected from
    ``s.cycle.ndim``) keeps its batch dim: ``(B, N, …) -> (B, R, C, …)``.
    """
    lead = s.cycle.ndim                       # 0 solo, 1 batched
    def rs(name, x):
        if name in _NODE_LEAVES:
            return x.reshape(x.shape[:lead] + (cfg.rows, cfg.cols)
                             + x.shape[lead + 1:])
        return x
    return SimState(**{k: rs(k, v) for k, v in s._asdict().items()})


def state_specs(cfg: SimConfig, row_axes, col_axes,
                batch_axes: Tuple[str, ...] = ()) -> SimState:
    """Per-leaf :class:`PartitionSpec` pytree for a (possibly batched)
    grid-shaped state.

    Node leaves shard ``(B?, R, C, …)`` over ``(batch_axes?, row_axes,
    col_axes)``; per-scenario leaves (stats, cycle, knobs) shard only
    their leading scenario axis (or are replicated in the solo case)."""
    d = {}
    for k in SimState._fields:
        if k in _NODE_LEAVES:
            d[k] = (P(batch_axes, row_axes, col_axes) if batch_axes
                    else P(row_axes, col_axes))
        else:
            d[k] = P(batch_axes) if batch_axes else P()
    return SimState(**d)


def _halo_transfer(out4: jnp.ndarray, vp4: jnp.ndarray,
                   row_axes, col_axes, nrow: int, ncol: int) -> jnp.ndarray:
    """Phase-3 transfer for one ``(…, Rt, Ct, 4, F)`` tile with ppermute
    halos.  Leading batch dims (the composed backend's local scenario
    axis) ride along unchanged — each directional halo slab is ONE
    ``ppermute`` regardless of batch size.

    ``nrow``/``ncol`` are the static tile-grid sizes (taken from the mesh
    by the caller — ``jax.lax.axis_size`` is unavailable on jax 0.4.x)."""
    perm_dn = [(i, (i + 1) % nrow) for i in range(nrow)]
    perm_up = [(i, (i - 1) % nrow) for i in range(nrow)]
    perm_rt = [(i, (i + 1) % ncol) for i in range(ncol)]
    perm_lt = [(i, (i - 1) % ncol) for i in range(ncol)]

    # input N (p=0) <- neighbour-above's output S (p=2)
    from_above = jax.lax.ppermute(out4[..., -1:, :, 2, :], row_axes, perm_dn)
    in_n = jnp.concatenate([from_above, out4[..., :-1, :, 2, :]], axis=-3)
    # input S (p=2) <- neighbour-below's output N (p=0)
    from_below = jax.lax.ppermute(out4[..., :1, :, 0, :], row_axes, perm_up)
    in_s = jnp.concatenate([out4[..., 1:, :, 0, :], from_below], axis=-3)
    # input W (p=3) <- left neighbour's output E (p=1)
    from_left = jax.lax.ppermute(out4[..., :, -1:, 1, :], col_axes, perm_rt)
    in_w = jnp.concatenate([from_left, out4[..., :, :-1, 1, :]], axis=-2)
    # input E (p=1) <- right neighbour's output W (p=3)
    from_right = jax.lax.ppermute(out4[..., :, :1, 3, :], col_axes, perm_lt)
    in_e = jnp.concatenate([out4[..., :, 1:, 3, :], from_right], axis=-2)

    inp = jnp.stack([in_n, in_e, in_s, in_w], axis=-2)   # (…, Rt, Ct, 4, F)
    # global mesh edges have no links: the valid-port mask kills wraparound
    return jnp.where(vp4[..., None], inp, 0)


def _flatten_nodes(x: jnp.ndarray) -> jnp.ndarray:
    return x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])


#: step builders keyed on (cfg, mesh, axes): two drivers over the same
#: decomposition share compiled programs (repeated buckets, benchmarks).
#: Bounded LRU — each entry pins jitted executables and device handles,
#: so a long-lived process sweeping many mesh shapes must not grow it
#: monotonically.
_BUILD_CACHE: OrderedDict = OrderedDict()
_BUILD_CACHE_MAX = 16


def make_sharded_step(cfg: SimConfig, mesh,
                      row_axes: Tuple[str, ...] = ("data",),
                      col_axes: Tuple[str, ...] = ("model",),
                      batch_axes: Tuple[str, ...] = ()):
    """Returns ``build(n_cycles)`` -> jitted sharded step advancing the sim
    by ``n_cycles`` cycles.

    With empty ``batch_axes`` this is the classic 2-D spatial step (a
    no-op once globally finished).  With ``batch_axes`` the state carries
    a leading scenario axis sharded over those mesh axes; within each
    tile the local scenarios are vmapped through the same per-tile cycle,
    and termination/freezing is *per scenario* (psum of the tile-local
    finished flags over the spatial axes only).

    Builders (and therefore compiled programs) are cached on
    ``(cfg, mesh, row_axes, col_axes, batch_axes)``, so drivers over the
    same decomposition never re-trace."""
    ckey = (cfg, mesh, tuple(row_axes), tuple(col_axes), tuple(batch_axes))
    if ckey in _BUILD_CACHE:
        _BUILD_CACHE.move_to_end(ckey)
        return _BUILD_CACHE[ckey]
    assert not cfg.centralized_directory and cfg.dir_layout == "home", \
        "sharded simulation requires the distributed, home-sharded directory"
    sspec = state_specs(cfg, row_axes, col_axes, batch_axes)
    gspec = (P(row_axes, col_axes), P(row_axes, col_axes),
             P(row_axes, col_axes), P(row_axes, col_axes))
    spatial_axes = tuple(row_axes) + tuple(col_axes)
    nrow = int(np.prod([mesh.shape[a] for a in row_axes]))
    ncol = int(np.prod([mesh.shape[a] for a in col_axes]))
    batched = bool(batch_axes)

    # sim.finished reduces over every axis when `cycle` is scalar, so it
    # serves unchanged as the tile-local termination predicate (vmapped
    # over the local scenario axis in the composed case)
    tile_finished = jax.vmap(_finished) if batched else _finished

    def one_cycle(flat: SimState, ctx: NodeCtx, rt: int, ct: int) -> SimState:
        # widen/narrow at the same per-cycle boundary as sim.cycle_step:
        # phases (and the halo slabs) compute in int32, the scan carry
        # stays in the storage layout.  Stats are folded per chunk in
        # step_tile (after the cross-tile psum), not here — the tile-
        # local low word has int32 headroom for any chunk length.
        dtypes = leaf_dtypes(cfg, flat.trace.shape[-1])
        flat = widen_state(flat)

        def p12(fs):
            s = phase1a(fs, cfg, ctx)
            s = phase1b(s, cfg, ctx)
            return phase2(s, cfg, ctx)

        vp4 = ctx.valid_port.reshape(rt, ct, 4)
        if batched:
            s, arb = jax.vmap(p12)(flat)
            bl = s.st.shape[0]
            out4 = arb.out.reshape(bl, rt, ct, 4, NUM_F)
            inp_next = _halo_transfer(out4, vp4, row_axes, col_axes,
                                      nrow, ncol)
            s = jax.vmap(lambda ss, ab, ip: deliver(ss, cfg, ctx, ab, ip))(
                s, arb, inp_next.reshape(bl, rt * ct, 4, NUM_F))
        else:
            s, arb = p12(flat)
            out4 = arb.out.reshape(rt, ct, 4, NUM_F)
            inp_next = _halo_transfer(out4, vp4, row_axes, col_axes,
                                      nrow, ncol)
            s = deliver(s, cfg, ctx, arb, inp_next.reshape(rt * ct, 4, NUM_F))
        return narrow_state(s._replace(cycle=s.cycle + 1), dtypes)

    def step_tile(n_cycles: int, sg: SimState, nid2, nr2, nc2, vp2):
        lead = 1 if batched else 0
        rt, ct = sg.st.shape[lead], sg.st.shape[lead + 1]
        ctx = NodeCtx(_flatten_nodes(nid2), _flatten_nodes(nr2),
                      _flatten_nodes(nc2), _flatten_nodes(vp2))

        def flat_of(s):  # (B?, Rt, Ct, …) -> (B?, Nl, …) for node leaves
            return SimState(**{
                k: (v.reshape(v.shape[:lead] + (rt * ct,) + v.shape[lead + 2:])
                    if k in _NODE_LEAVES else v)
                for k, v in s._asdict().items()})

        def grid_of(s):
            return SimState(**{
                k: (v.reshape(v.shape[:lead] + (rt, ct) + v.shape[lead + 1:])
                    if k in _NODE_LEAVES else v)
                for k, v in s._asdict().items()})

        flat = flat_of(sg)
        # stats start replicated (across spatial tiles) but accumulate
        # device-local sums inside the scan; the psum below re-replicates
        # the delta (the shard_map replication check is disabled for
        # exactly this carry).  Both words of the base-2**30 pair ride:
        # component deltas reconstruct the exact value sum, and one fold
        # after the psum restores the canonical (hi, lo) form — matching
        # the dense driver's per-cycle fold bit for bit at chunk edges.
        in_stats, in_hi = flat.stats, flat.stats_hi

        nspat = jax.lax.psum(jnp.ones((), I32), spatial_axes)

        def body(carry, _):
            fin_local = tile_finished(carry)        # () solo | (Bl,) batched
            fin = jax.lax.psum(fin_local.astype(I32), spatial_axes) == nspat
            nxt = one_cycle(carry, ctx, rt, ct)
            if batched:
                frz = lambda a, b: jnp.where(
                    fin.reshape(fin.shape + (1,) * (a.ndim - 1)), a, b)
            else:
                frz = lambda a, b: jnp.where(fin, a, b)
            return jax.tree.map(frz, carry, nxt), ()

        flat, _ = jax.lax.scan(body, flat, None, length=n_cycles)
        # stats: replicate across spatial tiles via psum of the local
        # delta (never across the scenario axis — those are independent)
        hi, lo = fold_stats(
            in_hi + jax.lax.psum(flat.stats_hi - in_hi, spatial_axes),
            in_stats + jax.lax.psum(flat.stats - in_stats, spatial_axes))
        flat = flat._replace(stats=lo, stats_hi=hi)
        return grid_of(flat)

    cache = {}

    def build(n_cycles: int):
        if n_cycles not in cache:
            smapped = _shard_map(
                functools.partial(step_tile, n_cycles),
                mesh=mesh,
                in_specs=(sspec,) + gspec,
                out_specs=sspec,
                **_SM_NOCHECK,
            )
            # donate the state (arg 0): in/out shardings and dtypes match
            # leaf for leaf, so XLA updates the mesh in place instead of
            # double-buffering it; the geometry args are reused each
            # chunk and are not donated
            cache[n_cycles] = jax.jit(smapped, donate_argnums=(0,))
        return cache[n_cycles]

    _BUILD_CACHE[ckey] = build
    while len(_BUILD_CACHE) > _BUILD_CACHE_MAX:
        _BUILD_CACHE.popitem(last=False)
    return build


def make_geo_arrays(cfg: SimConfig, mesh, row_axes=("data",),
                    col_axes=("model",)):
    """Global geometry arrays, laid out (R, C, …) and device_put sharded.

    Geometry has no scenario axis: on a 3-D composed mesh the arrays are
    replicated over the batch axes (every scenario shares one grid)."""
    geo = make_geometry(cfg.rows, cfg.cols)
    n, c = cfg.num_nodes, cfg.cols
    nid = np.arange(n, dtype=np.int32).reshape(cfg.rows, cfg.cols)
    nr = np.asarray(geo.node_r).reshape(cfg.rows, cfg.cols)
    nc = np.asarray(geo.node_c).reshape(cfg.rows, cfg.cols)
    vp = np.asarray(geo.valid_port).reshape(cfg.rows, cfg.cols, 4)
    sh = NamedSharding(mesh, P(row_axes, col_axes))
    return (jax.device_put(nid, sh), jax.device_put(nr, sh),
            jax.device_put(nc, sh), jax.device_put(vp, sh))


class ShardedSim:
    """Driver: host-chunked sharded simulation with global termination.

    Args:
        cfg: structural simulator config; must use the distributed
            home-sharded directory (``centralized_directory=False``,
            ``dir_layout="home"``), and ``rows``/``cols`` must be
            divisible by the spatial tile grid implied by the mesh.
        trace: ``(num_nodes, M)`` for a solo spatial run, or
            ``(B, num_nodes, M)`` for a composed batched run (then
            ``batch_axes`` must name the mesh axes the scenario dim is
            sharded over, and B must divide by their total size).
        mesh: a :class:`jax.sharding.Mesh` whose axes cover
            ``batch_axes + row_axes + col_axes``.
        row_axes / col_axes: mesh axes the simulated rows/columns are
            block-partitioned over.
        batch_axes: mesh axes for the scenario dim (composed backend);
            empty for the classic 2-D spatial decomposition.
        knobs: optional ``(migration, threshold, centralized, eject_age)``
            int32 vectors of length B — per-scenario traced policy knobs,
            as produced by :meth:`repro.core.sweep.SweepSpec.knob_arrays`.

    :meth:`run` returns one stats dict (solo) or a list of B dicts
    (batched), each bit-identical to the corresponding solo
    :func:`repro.core.sim.run`."""

    def __init__(self, cfg: SimConfig, trace: np.ndarray, mesh,
                 row_axes: Tuple[str, ...] = ("data",),
                 col_axes: Tuple[str, ...] = ("model",),
                 batch_axes: Tuple[str, ...] = (),
                 knobs: Optional[Tuple[np.ndarray, np.ndarray,
                                       np.ndarray, np.ndarray]] = None):
        nrow = int(np.prod([mesh.shape[a] for a in row_axes]))
        ncol = int(np.prod([mesh.shape[a] for a in col_axes]))
        assert cfg.rows % nrow == 0 and cfg.cols % ncol == 0, \
            f"mesh {cfg.rows}x{cfg.cols} not divisible by tiles {nrow}x{ncol}"
        trace = np.asarray(trace)
        if batch_axes:
            nb = int(np.prod([mesh.shape[a] for a in batch_axes]))
            assert trace.ndim == 3, "batch_axes requires a (B, N, M) trace"
            assert trace.shape[0] % nb == 0, \
                f"batch {trace.shape[0]} not divisible by {nb} scenario " \
                f"shard(s); pad like run_composed does"
        else:
            assert trace.ndim == 2, "a (B, N, M) trace requires batch_axes"
        self.cfg = cfg
        self.mesh = mesh
        self.batch = trace.shape[0] if batch_axes else None
        s = init_state(cfg, trace)
        if knobs is not None:
            mig, thr, cen, eja = knobs
            s = s._replace(knob_mig=jnp.asarray(mig, I32),
                           knob_mig_thr=jnp.asarray(thr, I32),
                           knob_central=jnp.asarray(cen, I32),
                           knob_ej_age=jnp.asarray(eja, I32))
        s = to_grid(s, cfg)
        specs = state_specs(cfg, row_axes, col_axes, batch_axes)
        self.state = jax.device_put(
            s, jax.tree.map(lambda p: NamedSharding(mesh, p), specs,
                            is_leaf=lambda x: isinstance(x, P)))
        self.geo = make_geo_arrays(cfg, mesh, row_axes, col_axes)
        self.build_step = make_sharded_step(cfg, mesh, row_axes, col_axes,
                                            batch_axes)
        self._finished = jax.jit(self._finished_fn)

    @staticmethod
    def _finished_fn(s: SimState) -> jnp.ndarray:
        return _finished(s)

    def run(self, max_cycles: Optional[int] = None, chunk: int = 256
            ) -> Union[Dict[str, int], List[Dict[str, int]]]:
        """Host-chunked driver.  Shares the driver-level termination and
        statistics machinery with :mod:`repro.core.sim` — including the
        livelock monitor, evaluated between chunks at host level (chunk
        granularity: progress must be absent across whole chunks, a
        strictly conservative version of the per-cycle in-graph monitor).

        Args:
            max_cycles: cycle cap (default ``cfg.max_cycles``); the tail
                chunk is clamped so an unfinished run stops at exactly
                this cycle, matching the dense backend bit-for-bit.
            chunk: simulated cycles per device dispatch (and per host
                termination/livelock check).

        Returns: one stats dict for a solo spatial sim, or a list of B
        dicts in scenario order for a composed batched sim."""
        check_cycle_cap(self.cfg, max_cycles)
        if self.batch is not None:
            return self._run_batched(max_cycles, chunk)
        return self._run_solo(max_cycles, chunk)

    def _run_solo(self, max_cycles, chunk):
        limit = max_cycles or self.cfg.max_cycles
        lw = self.cfg.livelock_window_effective
        prev_prog, frozen, abort = None, 0, 0
        while True:
            cyc = int(self.state.cycle)
            if cyc >= limit:
                break
            # clamp the last chunk so an unfinished run stops at exactly
            # max_cycles, matching the dense backend bit-for-bit (the
            # shorter tail program compiles once and is cached)
            n_step = min(chunk, limit - cyc)
            self.state = self.build_step(n_step)(self.state, *self.geo)
            if bool(self._finished(self.state)):
                break
            prog = tuple(np.asarray(self.state.stats)[_PROG_IDX].tolist())
            if prog == prev_prog:
                frozen += n_step
            else:
                prev_prog, frozen = prog, 0
            if lw and frozen >= lw:
                abort = ABORT_LIVELOCK
                break
        s = self.state
        z = np.int32(0)
        if abort:
            d = diag_counts(np.asarray(s.st), np.asarray(s.inp),
                             np.asarray(s.q_size))
            aux = ExecAux(
                abort=np.int32(abort),
                abort_cycle=np.asarray(s.cycle, np.int32),
                abort_stats=np.asarray(s.stats),
                abort_stats_hi=np.asarray(s.stats_hi), **d)
        else:
            zs = np.zeros_like(np.asarray(s.stats))
            aux = ExecAux(z, z, zs, zs, z, z, z, z, z)
        return stats_list(s, aux)[0]

    def _run_batched(self, max_cycles, chunk):
        """Composed-backend host loop: per-scenario termination and
        livelock accounting.  All *active* (unfinished, unaborted)
        scenarios share one clock — they step together each chunk; a
        finished scenario is frozen in-graph at its exact finish cycle,
        and an aborted one keeps stepping (like the dense driver) with
        its reported statistics snapshotted at the abort chunk edge."""
        limit = max_cycles or self.cfg.max_cycles
        lw = self.cfg.livelock_window_effective
        nb = self.batch
        nstats = int(self.state.stats.shape[-1])
        prev_prog: List = [None] * nb
        frozen = np.zeros(nb, np.int64)
        abort = np.zeros(nb, np.int32)
        ab_cycle = np.zeros(nb, np.int32)
        ab_stats = np.zeros((nb, nstats), np.int32)
        ab_hi = np.zeros((nb, nstats), np.int32)
        diag = {k: np.zeros(nb, np.int32)
                for k in ("circ", "wait_dir", "wait_data", "stalled", "dst0")}
        fin = np.asarray(self._finished(self.state))
        while True:
            active = ~fin & (abort == 0)
            if not active.any():
                break
            cyc = int(np.asarray(self.state.cycle)[active].max())
            if cyc >= limit:
                break
            n_step = min(chunk, limit - cyc)
            self.state = self.build_step(n_step)(self.state, *self.geo)
            # one predicate evaluation per chunk: this post-step vector
            # is both the monitor's not-finished guard and the next
            # iteration's activity mask
            fin = np.asarray(self._finished(self.state))
            if not lw:
                continue
            stats = np.asarray(self.state.stats)
            stats_hi = np.asarray(self.state.stats_hi)
            cyc_now = np.asarray(self.state.cycle)
            st = inp = qs = None
            for b in np.nonzero(active)[0]:
                prog = stats[b, _PROG_IDX].tobytes()
                if prog == prev_prog[b]:
                    frozen[b] += n_step
                else:
                    prev_prog[b], frozen[b] = prog, 0
                if frozen[b] >= lw and not fin[b]:
                    abort[b] = ABORT_LIVELOCK
                    ab_cycle[b] = int(cyc_now[b])
                    ab_stats[b] = stats[b]
                    ab_hi[b] = stats_hi[b]
                    if st is None:   # pull the big arrays at most once
                        st = np.asarray(self.state.st)
                        inp = np.asarray(self.state.inp)
                        qs = np.asarray(self.state.q_size)
                    for k, v in diag_counts(st[b], inp[b], qs[b]).items():
                        diag[k][b] = v
        aux = ExecAux(abort=abort, abort_cycle=ab_cycle, abort_stats=ab_stats,
                      abort_stats_hi=ab_hi,
                      circ=diag["circ"], wait_dir=diag["wait_dir"],
                      wait_data=diag["wait_data"], stalled=diag["stalled"],
                      dst0=diag["dst0"])
        return stats_list(self.state, aux)


def run_composed(spec, grid: Tuple[int, int, int],
                 max_cycles: Optional[int] = None, chunk: int = 256,
                 devices: Optional[Sequence] = None
                 ) -> List[Dict[str, int]]:
    """Composed backend: B scenarios × spatial tiles on one 3-D device mesh.

    Args:
        spec: a :class:`repro.core.sweep.SweepSpec` — the scenarios'
            workloads and traced policy knobs over one structural config
            (``dir_layout`` is forced to ``"home"`` here; a centralized-
            directory scenario is therefore rejected by validation).
        grid: ``(batch_shards, row_tiles, col_tiles)`` device grid; its
            product is the number of devices used.  ``(1, rt, ct)``
            degenerates to the spatial backend; ``(1, 1, 1)`` to a solo
            run — both bit-identically.
        max_cycles: cycle cap (default ``cfg.max_cycles``).
        chunk: simulated cycles per device dispatch.
        devices: device list to build the mesh from (default
            ``jax.devices()``); must hold at least ``prod(grid)``.

    The scenario batch is padded up to a multiple of ``batch_shards``
    with copies of the last scenario exactly like
    :func:`repro.core.sweep.run_sweep` (copies finish the same cycle as
    their original, so padding costs no wall-clock and is dropped from
    the results).

    Returns: one stats dict per scenario, in scenario order,
    bit-identical to solo :func:`repro.core.sim.run` calls."""
    from .sweep import SweepSpec   # deferred: avoid an import cycle
    bs, rt, ct = grid
    cfg = dataclasses.replace(spec.cfg, dir_layout="home")
    spec = SweepSpec(cfg, spec.scenarios)
    spec.validate()
    traces = spec.traces()
    mig, thr, cen, eja = spec.knob_arrays()
    pad = (-spec.size) % bs
    if pad:
        traces = np.concatenate([traces, np.repeat(traces[-1:], pad, 0)])
        mig, thr, cen, eja = (np.concatenate([a, np.repeat(a[-1:], pad, 0)])
                              for a in (mig, thr, cen, eja))
    devs = list(devices if devices is not None else jax.devices())
    need = bs * rt * ct
    if len(devs) < need:
        raise ValueError(f"composed grid {grid} needs {need} device(s), "
                         f"have {len(devs)}")
    mesh = Mesh(np.asarray(devs[:need]).reshape(bs, rt, ct),
                ("scenario", "data", "model"))
    sim = ShardedSim(cfg, traces, mesh, row_axes=("data",),
                     col_axes=("model",), batch_axes=("scenario",),
                     knobs=(mig, thr, cen, eja))
    return sim.run(max_cycles, chunk=chunk)[:spec.size]
