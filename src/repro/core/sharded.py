"""Multi-device NoC simulation: 2-D spatial domain decomposition (DESIGN §5).

The simulated router grid (R, C) is block-partitioned over the TPU device
mesh: rows over ``row_axes`` (e.g. ``("pod", "data")``), columns over
``col_axes`` (e.g. ``("model",)``).  Every phase is node-local except the
phase-3 flit transfer, whose cross-tile edges become four ``ppermute`` halo
slabs per cycle — the simulated 2-D mesh maps onto the physical 2-D ICI
torus, so halo traffic is near-neighbour on the real interconnect.

The directory must be distributed (``dir_layout="home"``): entry(tag) lives
at node ``tag % N`` which is the only node that ever touches it, so the
location array shards exactly like the nodes and directory traffic rides
the simulated network itself (no extra collectives).
"""
from __future__ import annotations

import functools
import inspect
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .cache import phase1a, phase1b
from .config import ST_WAIT_DATA, ST_WAIT_DIR, SimConfig
from .noc import deliver, phase2
from .sim import (ABORT_LIVELOCK, ExecAux, _PROG_IDX, finished as _finished,
                  stats_list)
from .state import (
    F_DST,
    F_VALID,
    NUM_F,
    NodeCtx,
    SimState,
    init_state,
    make_geometry,
)

# jax >= 0.5 exports shard_map at the top level; 0.4.x keeps it in
# experimental.  The replication-check kwarg was also renamed
# (check_rep -> check_vma); stats leave the tile replicated but become
# device-varying inside the scan (re-replicated via psum), so the check
# must be off either way.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map
_SM_NOCHECK = {
    ("check_vma" if "check_vma" in inspect.signature(_shard_map).parameters
     else "check_rep"): False}

I32 = jnp.int32

#: leaves whose leading dim is the node dim (reshaped (N, …) -> (R, C, …))
_NODE_LEAVES = {
    "st", "ctr", "tr_ptr", "pend_addr", "install_mode", "pkt_ctr",
    "lru_clock", "l1_tag", "l1_lru", "l1_owner", "l2_tag", "l2_lru",
    "l2_mig", "l2_last", "l2_streak", "dir_loc", "fwd_tag", "fwd_dst",
    "fwd_ptr", "inp", "q_desc", "q_head", "q_size", "q_fid", "rob", "pc",
    "trace",
}
_REPL_LEAVES = {"stats", "cycle"}


def to_grid(s: SimState, cfg: SimConfig) -> SimState:
    """Reshape node-major leaves (N, …) -> (R, C, …)."""
    def rs(name, x):
        if name in _NODE_LEAVES:
            return x.reshape((cfg.rows, cfg.cols) + x.shape[1:])
        return x
    return SimState(**{k: rs(k, v) for k, v in s._asdict().items()})


def state_specs(cfg: SimConfig, row_axes, col_axes) -> SimState:
    d = {}
    for k in SimState._fields:
        d[k] = P(row_axes, col_axes) if k in _NODE_LEAVES else P()
    return SimState(**d)


def _halo_transfer(out4: jnp.ndarray, vp4: jnp.ndarray,
                   row_axes, col_axes, nrow: int, ncol: int) -> jnp.ndarray:
    """Phase-3 transfer for one (Rt, Ct, 4, F) tile with ppermute halos.

    ``nrow``/``ncol`` are the static tile-grid sizes (taken from the mesh
    by the caller — ``jax.lax.axis_size`` is unavailable on jax 0.4.x)."""
    perm_dn = [(i, (i + 1) % nrow) for i in range(nrow)]
    perm_up = [(i, (i - 1) % nrow) for i in range(nrow)]
    perm_rt = [(i, (i + 1) % ncol) for i in range(ncol)]
    perm_lt = [(i, (i - 1) % ncol) for i in range(ncol)]

    # input N (p=0) <- neighbour-above's output S (p=2)
    from_above = jax.lax.ppermute(out4[-1:, :, 2], row_axes, perm_dn)
    in_n = jnp.concatenate([from_above, out4[:-1, :, 2]], axis=0)
    # input S (p=2) <- neighbour-below's output N (p=0)
    from_below = jax.lax.ppermute(out4[:1, :, 0], row_axes, perm_up)
    in_s = jnp.concatenate([out4[1:, :, 0], from_below], axis=0)
    # input W (p=3) <- left neighbour's output E (p=1)
    from_left = jax.lax.ppermute(out4[:, -1:, 1], col_axes, perm_rt)
    in_w = jnp.concatenate([from_left, out4[:, :-1, 1]], axis=1)
    # input E (p=1) <- right neighbour's output W (p=3)
    from_right = jax.lax.ppermute(out4[:, :1, 3], col_axes, perm_lt)
    in_e = jnp.concatenate([out4[:, 1:, 3], from_right], axis=1)

    inp = jnp.stack([in_n, in_e, in_s, in_w], axis=2)   # (Rt, Ct, 4, F)
    # global mesh edges have no links: the valid-port mask kills wraparound
    return jnp.where(vp4[:, :, :, None], inp, 0)


def _flatten_nodes(x: jnp.ndarray) -> jnp.ndarray:
    return x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])


def make_sharded_step(cfg: SimConfig, mesh,
                      row_axes: Tuple[str, ...] = ("data",),
                      col_axes: Tuple[str, ...] = ("model",)):
    """Returns ``build(n_cycles)`` -> jitted sharded step advancing the sim
    by ``n_cycles`` cycles (a no-op once globally finished)."""
    assert not cfg.centralized_directory and cfg.dir_layout == "home", \
        "sharded simulation requires the distributed, home-sharded directory"
    sspec = state_specs(cfg, row_axes, col_axes)
    gspec = (P(row_axes, col_axes), P(row_axes, col_axes),
             P(row_axes, col_axes), P(row_axes, col_axes))
    all_axes = tuple(row_axes) + tuple(col_axes)
    nrow = int(np.prod([mesh.shape[a] for a in row_axes]))
    ncol = int(np.prod([mesh.shape[a] for a in col_axes]))

    # sim.finished reduces over every axis when `cycle` is scalar, so it
    # serves unchanged as the tile-local termination predicate
    tile_finished = _finished

    def one_cycle(flat: SimState, ctx: NodeCtx, rt: int, ct: int) -> SimState:
        s = phase1a(flat, cfg, ctx)
        s = phase1b(s, cfg, ctx)
        s, arb = phase2(s, cfg, ctx)
        out4 = arb.out.reshape(rt, ct, 4, NUM_F)
        vp4 = ctx.valid_port.reshape(rt, ct, 4)
        inp_next = _halo_transfer(out4, vp4, row_axes, col_axes, nrow, ncol)
        s = deliver(s, cfg, ctx, arb, inp_next.reshape(rt * ct, 4, NUM_F))
        return s._replace(cycle=s.cycle + 1)

    def step_tile(n_cycles: int, s2d: SimState, nid2, nr2, nc2, vp2):
        rt, ct = s2d.st.shape
        ctx = NodeCtx(_flatten_nodes(nid2), _flatten_nodes(nr2),
                      _flatten_nodes(nc2), _flatten_nodes(vp2))

        def flat_of(s):  # (Rt, Ct, …) -> (Nl, …) for node leaves
            return SimState(**{
                k: (_flatten_nodes(v) if k in _NODE_LEAVES else v)
                for k, v in s._asdict().items()})

        def grid_of(s):
            return SimState(**{
                k: (v.reshape((rt, ct) + v.shape[1:]) if k in _NODE_LEAVES
                    else v)
                for k, v in s._asdict().items()})

        flat = flat_of(s2d)
        # stats start replicated but accumulate device-local sums inside
        # the scan; the psum below re-replicates the delta (the shard_map
        # replication check is disabled for exactly this carry)
        in_stats = flat.stats

        ndev = jax.lax.psum(jnp.ones((), I32), all_axes)

        def body(carry, _):
            fin_local = tile_finished(carry)
            fin = jax.lax.psum(fin_local.astype(I32), all_axes) == ndev
            nxt = one_cycle(carry, ctx, rt, ct)
            out = jax.tree.map(lambda a, b: jnp.where(fin, a, b), carry, nxt)
            return out, ()

        flat, _ = jax.lax.scan(body, flat, None, length=n_cycles)
        # stats: replicate via psum of the local delta
        delta = flat.stats - in_stats
        flat = flat._replace(stats=in_stats + jax.lax.psum(delta, all_axes))
        return grid_of(flat)

    cache = {}

    def build(n_cycles: int):
        if n_cycles not in cache:
            smapped = _shard_map(
                functools.partial(step_tile, n_cycles),
                mesh=mesh,
                in_specs=(sspec,) + gspec,
                out_specs=sspec,
                **_SM_NOCHECK,
            )
            cache[n_cycles] = jax.jit(smapped)
        return cache[n_cycles]

    return build


def make_geo_arrays(cfg: SimConfig, mesh, row_axes=("data",),
                    col_axes=("model",)):
    """Global geometry arrays, laid out (R, C, …) and device_put sharded."""
    geo = make_geometry(cfg.rows, cfg.cols)
    n, c = cfg.num_nodes, cfg.cols
    nid = np.arange(n, dtype=np.int32).reshape(cfg.rows, cfg.cols)
    nr = np.asarray(geo.node_r).reshape(cfg.rows, cfg.cols)
    nc = np.asarray(geo.node_c).reshape(cfg.rows, cfg.cols)
    vp = np.asarray(geo.valid_port).reshape(cfg.rows, cfg.cols, 4)
    sh = NamedSharding(mesh, P(row_axes, col_axes))
    return (jax.device_put(nid, sh), jax.device_put(nr, sh),
            jax.device_put(nc, sh), jax.device_put(vp, sh))


class ShardedSim:
    """Driver: host-chunked sharded simulation with global termination."""

    def __init__(self, cfg: SimConfig, trace: np.ndarray, mesh,
                 row_axes: Tuple[str, ...] = ("data",),
                 col_axes: Tuple[str, ...] = ("model",)):
        nrow = int(np.prod([mesh.shape[a] for a in row_axes]))
        ncol = int(np.prod([mesh.shape[a] for a in col_axes]))
        assert cfg.rows % nrow == 0 and cfg.cols % ncol == 0, \
            f"mesh {cfg.rows}x{cfg.cols} not divisible by tiles {nrow}x{ncol}"
        self.cfg = cfg
        self.mesh = mesh
        s = to_grid(init_state(cfg, trace), cfg)
        specs = state_specs(cfg, row_axes, col_axes)
        self.state = jax.device_put(
            s, jax.tree.map(lambda p: NamedSharding(mesh, p), specs,
                            is_leaf=lambda x: isinstance(x, P)))
        self.geo = make_geo_arrays(cfg, mesh, row_axes, col_axes)
        self.build_step = make_sharded_step(cfg, mesh, row_axes, col_axes)
        self._finished = jax.jit(self._finished_fn)

    @staticmethod
    def _finished_fn(s: SimState) -> jnp.ndarray:
        return _finished(s)

    def run(self, max_cycles=None, chunk: int = 256):
        """Host-chunked driver.  Shares the driver-level termination and
        statistics machinery with :mod:`repro.core.sim` — including the
        livelock monitor, evaluated between chunks at host level (chunk
        granularity: progress must be absent across whole chunks, a
        strictly conservative version of the per-cycle in-graph monitor)."""
        limit = max_cycles or self.cfg.max_cycles
        lw = self.cfg.livelock_window_effective
        prev_prog, frozen, abort = None, 0, 0
        while True:
            cyc = int(self.state.cycle)
            if cyc >= limit:
                break
            # clamp the last chunk so an unfinished run stops at exactly
            # max_cycles, matching the dense backend bit-for-bit (the
            # shorter tail program compiles once and is cached)
            n_step = min(chunk, limit - cyc)
            self.state = self.build_step(n_step)(self.state, *self.geo)
            if bool(self._finished(self.state)):
                break
            prog = tuple(np.asarray(self.state.stats)[_PROG_IDX].tolist())
            if prog == prev_prog:
                frozen += n_step
            else:
                prev_prog, frozen = prog, 0
            if lw and frozen >= lw:
                abort = ABORT_LIVELOCK
                break
        s = self.state
        z = np.int32(0)
        if abort:
            inp = np.asarray(s.inp)                  # (R, C, 4, F)
            st = np.asarray(s.st)
            valid = inp[..., F_VALID] > 0
            aux = ExecAux(
                abort=np.int32(abort),
                abort_cycle=np.asarray(s.cycle, np.int32),
                abort_stats=np.asarray(s.stats),
                circ=np.int32(valid.sum()),
                wait_dir=np.int32((st == ST_WAIT_DIR).sum()),
                wait_data=np.int32((st == ST_WAIT_DATA).sum()),
                stalled=np.int32((np.asarray(s.q_size) > 0).sum()),
                dst0=np.int32((valid & (inp[..., F_DST] == 0)).sum()),
            )
        else:
            aux = ExecAux(z, z, np.zeros_like(np.asarray(s.stats)),
                          z, z, z, z, z)
        return stats_list(s, aux)[0]
