"""Traffic-generator registry and the one source grammar.

Every trace source the simulator can synthesize — the paper's five
application models, the uniform ``random`` injector, the ``loop``
reference generators, the synthetic NoC patterns — is a registered
:class:`TrafficGen`.  Validation (:func:`valid_source`), dispatch
(:func:`resolve`), CLI help and error text (:func:`source_help`,
:func:`source_summary`) all derive from the same registry, so adding a
generator is ONE :func:`register` call: it immediately becomes reachable
from ``resolve_trace``, ``stacked_traces``, manifests, ``--app``, the
zoo, and the generated ``docs/cli.md``.

Grammar (one spelling everywhere)::

    name                    # defaults for every parameter
    name:key=val,key=val    # keyword parameters
    name:val                # positional (mapped by TrafficGen.positional)

``loop:matmul`` — the historical spelling of the per-node-loop reference
generator — parses as generator ``loop`` with positional ``app=matmul``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Tuple

import numpy as np

from ..config import SimConfig

__all__ = ["Param", "TrafficGen", "register", "get_gen", "gen_names",
           "parse_source", "valid_source", "resolve", "source_help",
           "source_summary"]


@dataclasses.dataclass(frozen=True)
class Param:
    """One tunable parameter of a :class:`TrafficGen`.

    Attributes:
        default: value used when the source spec omits the parameter.
        typ: coercion applied to the spec's string value (``float`` /
            ``int`` / ``str``).
        help: one-line description (surfaces in :func:`source_help`).
        lo: inclusive lower bound (``None`` = unbounded).
        hi: inclusive upper bound (``None`` = unbounded).
        choices: closed set of admissible values (``None`` = any).
    """

    default: object
    typ: Callable = float
    help: str = ""
    lo: Optional[float] = None
    hi: Optional[float] = None
    choices: Optional[Tuple] = None

    def coerce(self, raw, *, source: str):
        """Parse + bounds-check one raw value; raises ``ValueError`` with
        the offending ``source`` spec named."""
        try:
            v = self.typ(raw)
        except (TypeError, ValueError):
            raise ValueError(
                f"source {source!r}: cannot parse {raw!r} as "
                f"{self.typ.__name__}") from None
        if self.choices is not None and v not in self.choices:
            raise ValueError(f"source {source!r}: {v!r} not in "
                             f"{sorted(self.choices)}")
        if self.lo is not None and v < self.lo:
            raise ValueError(f"source {source!r}: {v!r} < {self.lo}")
        if self.hi is not None and v > self.hi:
            raise ValueError(f"source {source!r}: {v!r} > {self.hi}")
        return v


@dataclasses.dataclass(frozen=True)
class TrafficGen:
    """A registered trace source.

    Attributes:
        name: registry key — the first token of the source grammar.
        fn: ``fn(cfg, refs_per_core, seed, **params) -> (N, M) int32``
            address trace (``-1`` is the trace-exhaustion sentinel and
            must never appear as a generated address).
        kind: coarse family tag — ``"app"`` (representative application
            model), ``"injector"`` (uniform random), ``"reference"``
            (per-node-loop golden generators), ``"pattern"`` (synthetic
            NoC destination patterns).
        help: one-line description for CLI/docs.
        params: name → :class:`Param` spec of the tunables.
        positional: parameter names bare (``key``-less) grammar tokens
            map to, in order — e.g. ``loop:matmul`` == ``loop:app=matmul``.
    """

    name: str
    fn: Callable[..., np.ndarray]
    kind: str = "app"
    help: str = ""
    params: Mapping[str, Param] = dataclasses.field(default_factory=dict)
    positional: Tuple[str, ...] = ()

    def spec(self, **params) -> str:
        """The canonical grammar string for this generator with
        ``params`` (defaults omitted) — the inverse of
        :func:`parse_source`."""
        items = [f"{k}={params[k]}" for k in self.params
                 if k in params and params[k] != self.params[k].default]
        return self.name + (":" + ",".join(items) if items else "")


_REGISTRY: Dict[str, TrafficGen] = {}


def register(gen: TrafficGen) -> TrafficGen:
    """Add ``gen`` to the registry (its ``name`` must be new) and return
    it, so modules can register at import time."""
    if gen.name in _REGISTRY:
        raise ValueError(f"traffic generator {gen.name!r} already registered")
    _REGISTRY[gen.name] = gen
    return gen


def gen_names(kind: Optional[str] = None) -> Tuple[str, ...]:
    """Registered generator names (insertion order), optionally filtered
    by ``kind``."""
    return tuple(n for n, g in _REGISTRY.items()
                 if kind is None or g.kind == kind)


def get_gen(name: str) -> TrafficGen:
    """Look up a generator by registry ``name``; ``ValueError`` (with the
    full registry listed) on an unknown name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown trace source {name!r}; "
                         + source_summary()) from None


def parse_source(spec: str) -> Tuple[TrafficGen, Dict[str, object]]:
    """Parse a source spec (``name`` or ``name:key=val,...``) into its
    generator and a fully-defaulted, validated parameter dict.

    Raises ``ValueError`` — with registry-derived help — on an unknown
    generator, unknown/duplicate parameter, unparsable value, or a bare
    token beyond the generator's positional slots."""
    name, _, argstr = spec.partition(":")
    gen = get_gen(name.strip())
    params = {k: p.default for k, p in gen.params.items()}
    pos = 0
    if argstr.strip():
        seen = set()
        for tok in argstr.split(","):
            tok = tok.strip()
            if not tok:
                continue
            if "=" in tok:
                key, _, raw = tok.partition("=")
                key = key.strip()
            else:
                if pos >= len(gen.positional):
                    raise ValueError(
                        f"source {spec!r}: unexpected bare value {tok!r} "
                        f"({gen.name} takes "
                        f"{len(gen.positional)} positional parameter(s): "
                        f"{list(gen.positional)})")
                key, raw = gen.positional[pos], tok
                pos += 1
            if key not in gen.params:
                raise ValueError(
                    f"source {spec!r}: unknown parameter {key!r} for "
                    f"{gen.name!r}; parameters: {sorted(gen.params)}")
            if key in seen:
                raise ValueError(f"source {spec!r}: duplicate parameter "
                                 f"{key!r}")
            seen.add(key)
            params[key] = gen.params[key].coerce(raw.strip(), source=spec)
    return gen, params


def valid_source(spec: str) -> bool:
    """Does ``spec`` parse against the registry?  Exactly the set of
    names :func:`resolve` accepts — validation and dispatch share
    :func:`parse_source`."""
    try:
        parse_source(spec)
        return True
    except ValueError:
        return False


def resolve(cfg: SimConfig, spec: str, refs_per_core: int,
            seed: int) -> np.ndarray:
    """Synthesize the ``(num_nodes, refs_per_core)`` trace for ``spec``:
    parse the source against the registry, then call its generator with
    ``cfg``/``refs_per_core``/``seed`` and the parsed parameters."""
    gen, params = parse_source(spec)
    return gen.fn(cfg, refs_per_core, seed, **params)


def source_summary() -> str:
    """One-line registry roll-call used by error messages — kinds with
    their generator names, plus the grammar reminder."""
    kinds = []
    for kind in dict.fromkeys(g.kind for g in _REGISTRY.values()):
        names = ", ".join(gen_names(kind))
        kinds.append(f"{kind}s: {names}")
    return ("known sources — " + "; ".join(kinds)
            + " (grammar: name or name:key=val,...)")


def source_help() -> str:
    """Multi-line per-generator help — one line per generator with its
    kind, parameters (name=default, plus each parameter's description)
    and summary.  Rendered into the generated ``docs/cli.md`` "Workload
    sources" section by ``scripts/gen_cli_docs.py`` (the short
    roll-call in the ``--app`` flag help is :func:`source_summary`)."""
    lines = []
    for g in _REGISTRY.values():
        ps = "; ".join(f"{k}={p.default} ({p.help})" if p.help
                       else f"{k}={p.default}"
                       for k, p in g.params.items())
        lines.append(f"{g.name} [{g.kind}]: {g.help}"
                     + (f"\n    params: {ps}" if ps else ""))
    return "\n".join(lines)
