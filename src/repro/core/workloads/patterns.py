"""Synthetic NoC traffic patterns, realized as address streams.

The deflection-routing literature (e.g. Ausavarungnirun & Mutlu's
deflection-network studies, Dally & Towles ch. 3) stresses bufferless
networks with classic destination patterns — transpose, bit-complement,
hotspot, tornado, neighbor — at controlled injection rates.  This
simulator is trace-driven: a node consumes *addresses*, and network
traffic materializes from the cache/directory protocol.  Each pattern is
therefore realized as an address stream whose **directory home nodes**
form the target destination pattern:

* with a distributed directory the home of tag ``t`` is ``t % N``
  (:func:`repro.core.cache.dir_home_v`), so a reference to a fresh tag
  ``dst + k*N`` makes the source send a 1-flit DA to exactly ``dst``
  (and receive the DR back; the later victim DU rides the same pair);
* a reference whose tag is congruent to the *source* node is handled
  inline (no flits) — these "filler" references implement the
  injection-rate throttle: with probability ``1 - rate`` a reference
  re-touches a tiny node-local hot set (cache-hot after first touch)
  instead of injecting pattern traffic.

The patterns assume a **distributed** directory
(``centralized_directory=False``); under the paper-default centralized
directory every home is node 0 and any pattern degenerates to the
node-0 hotspot.  The zoo families (:mod:`repro.core.zoo`) set this up.

Destination maps for source ``(r, c)`` on a ``rows x cols`` mesh:

=============  ==========================================================
transpose      ``(r, c) -> (c, r)`` as index ``c*rows + r`` (works on
               non-square meshes too; classic matrix-transpose stress)
bitcomp        index ``i -> N-1-i`` (bitwise complement for power-of-two
               ``N``); maximal-distance corner-to-corner crossing
tornado        half-ring shift in both dimensions:
               ``((r + rows//2) % rows, (c + cols//2) % cols)`` —
               adversarial for dimension-ordered-style deflection routing
neighbor       ``(r, c) -> (r, (c+1) % cols)`` — best-case 1-hop traffic
hotspot        fraction ``frac`` of pattern references target one of
               ``hot`` evenly-spaced hot nodes; the rest are uniform
=============  ==========================================================

All generators are pure functions of ``(cfg, refs_per_core, seed,
params)`` and emit ``(N, M) int32`` addresses with no ``-1`` (the
exhaustion sentinel is reserved for padding by ``stacked_traces``).
"""
from __future__ import annotations

import numpy as np

from ..config import SimConfig
from .base import Param, TrafficGen, register

__all__ = ["pattern_trace", "PATTERN_NAMES", "dst_map"]

#: registered synthetic-pattern generator names (registration order).
PATTERN_NAMES = ("transpose", "bitcomp", "hotspot", "tornado", "neighbor")

#: per-node local hot-set size for filler (sub-``rate``) references.
_FILLER_HOT = 4


def _pat_seed(name: str, seed: int):
    # same stable-hash construction as apps._app_seed, offset so a pattern
    # and an app with the same seed never share a stream
    stable = sum(ord(ch) * (i + 1) for i, ch in enumerate(name)) % 65536
    return np.random.SeedSequence([0x5E7A, stable, seed])


def _rc(cfg: SimConfig):
    i = np.arange(cfg.num_nodes, dtype=np.int64)
    return i // cfg.cols, i % cfg.cols


def dst_map(cfg: SimConfig, name: str) -> np.ndarray:
    """The ``(N,)`` destination-node map of a deterministic pattern
    (``transpose`` / ``bitcomp`` / ``tornado`` / ``neighbor``) for
    ``cfg``'s mesh — the ground truth the property tests assert against.
    ``hotspot`` is stochastic and has no fixed map (``ValueError``)."""
    r, c = _rc(cfg)
    if name == "transpose":
        return (c * cfg.rows + r).astype(np.int64)
    if name == "bitcomp":
        return cfg.num_nodes - 1 - np.arange(cfg.num_nodes, dtype=np.int64)
    if name == "tornado":
        return (((r + cfg.rows // 2) % cfg.rows) * cfg.cols
                + (c + cfg.cols // 2) % cfg.cols)
    if name == "neighbor":
        return (r * cfg.cols + (c + 1) % cfg.cols).astype(np.int64)
    raise ValueError(f"pattern {name!r} has no deterministic destination "
                     f"map; deterministic patterns: "
                     f"{[n for n in PATTERN_NAMES if n != 'hotspot']}")


def pattern_trace(cfg: SimConfig, refs_per_core: int, seed: int,
                  dst, rate: float, name: str) -> np.ndarray:
    """Synthesize the address stream realizing a destination pattern.

    Args:
        cfg: simulated machine (mesh + address-space geometry).
        refs_per_core: references per node (the trace's ``M``).
        seed: RNG seed; the stream is a pure function of
            ``(cfg, name, seed, params)``.
        dst: destination node per reference — ``(N,)`` (broadcast over
            references) or ``(N, M)``.
        rate: injection rate in ``[0, 1]`` — probability a reference
            carries pattern traffic; the rest re-touch a node-local
            hot set (home == self, so no network traffic after the
            first-touch memory fill).
        name: pattern name (seeds the per-pattern RNG stream).

    Returns: ``(N, M) int32`` addresses.  A pattern reference uses tag
    ``dst + k*N`` with ``k`` uniform over the tag space, so its
    directory home is exactly ``dst`` and repeated tags (which would be
    cache-hot and silent) are rare.

    Raises ``ValueError`` when the directory has fewer entries than the
    mesh has nodes: the home map ``tag % N`` then cannot reach every
    destination and the ``% entries`` wrap would silently scramble both
    the pattern and the rate throttle — grow ``cfg.addr_bits`` (or
    shrink ``cfg.cache.l2_block``) instead."""
    n, m = cfg.num_nodes, refs_per_core
    if cfg.dir_entries < n:
        raise ValueError(
            f"pattern {name!r} needs at least one directory entry per "
            f"node to realize destination homes, but dir_entries="
            f"{cfg.dir_entries} < num_nodes={n} "
            f"(addr_bits={cfg.addr_bits}, l2_block={cfg.cache.l2_block}); "
            "increase addr_bits")
    g = np.random.default_rng(np.random.PCG64(_pat_seed(name, seed)))
    entries = cfg.dir_entries
    k_span = max(1, entries // n)
    dst = np.asarray(dst, np.int64)
    if dst.ndim == 1:
        dst = dst[:, None]

    nodes = np.arange(n, dtype=np.int64)[:, None]
    kdraw = g.integers(0, k_span, (n, m))
    is_pat = g.random((n, m)) < rate
    # filler hot set: tags congruent to the own node id → inline directory,
    # cache-hot after first touch
    hot = nodes + g.integers(0, k_span, (n, _FILLER_HOT)) * n
    filler = np.take_along_axis(hot, g.integers(0, _FILLER_HOT, (n, m)),
                                axis=1)
    tag = np.where(is_pat, dst + kdraw * n, filler) % entries
    return (tag << cfg.cache.l2_shift).astype(np.int32)


def _hotspot_dst(cfg: SimConfig, g: np.random.Generator, m: int,
                 frac: float, hot: int) -> np.ndarray:
    n = cfg.num_nodes
    hot = min(hot, n)
    hot_ids = (np.arange(hot, dtype=np.int64) * n) // hot   # evenly spaced
    pick = g.integers(0, hot, (n, m))
    uni = g.integers(0, n, (n, m))
    return np.where(g.random((n, m)) < frac, hot_ids[pick], uni)


_RATE = Param(1.0, float, "injection rate: fraction of references that "
                          "carry pattern traffic", lo=0.0, hi=1.0)


def _make_perm(pname: str, helptext: str) -> TrafficGen:
    def fn(cfg, refs, seed, rate=1.0, _p=pname):
        return pattern_trace(cfg, refs, seed, dst_map(cfg, _p), rate, _p)
    return TrafficGen(name=pname, kind="pattern", help=helptext,
                      params={"rate": _RATE}, positional=("rate",), fn=fn)


register(_make_perm(
    "transpose", "destination (c, r): matrix-transpose permutation"))
register(_make_perm(
    "bitcomp", "destination N-1-i (bit-complement): maximal-distance "
               "corner-to-corner crossing"))


def _hotspot_fn(cfg, refs, seed, rate=1.0, frac=0.5, hot=1):
    g = np.random.default_rng(np.random.PCG64(_pat_seed("hotspot@", seed)))
    dst = _hotspot_dst(cfg, g, refs, frac, hot)
    return pattern_trace(cfg, refs, seed, dst, rate, "hotspot")


register(TrafficGen(
    name="hotspot", kind="pattern",
    help="fraction `frac` of pattern references target `hot` evenly-spaced "
         "hot nodes, the rest are uniform random",
    params={"rate": _RATE,
            "frac": Param(0.5, float, "fraction of pattern references "
                                      "aimed at the hot nodes",
                          lo=0.0, hi=1.0),
            "hot": Param(1, int, "number of hot nodes", lo=1)},
    positional=("frac",),
    fn=_hotspot_fn))

register(_make_perm(
    "tornado", "half-ring shift in both mesh dimensions: adversarial "
               "long-haul traffic for deflection routing"))
register(_make_perm(
    "neighbor", "destination (r, c+1 mod cols): best-case 1-hop traffic"))
