"""Application trace models (paper §6.2.3) + the uniform injector.

The paper feeds the simulator "representative traces" produced by Multi2sim
for five applications (matmul, apsi, mgrid, wupwise, equake) with ``M``
(=200) address references per core, and notes Multi2sim cannot produce traces
beyond ~100 cores.  We reproduce the *representative trace* methodology with
parameterized per-application access-pattern models that scale to any core
count, plus uniform-random traffic and traces derived from an LM model's
layer schedule (so the trace source scales with the simulated machine, which
is exactly the capability gap the paper calls out).

A trace is an ``(num_nodes, M) int32`` array of byte addresses, ``-1`` padded.

Synthesis is fully vectorized numpy sampling (node-slab batches of fixed
size, so output is independent of mesh size vs slab boundaries): at 100k+
cores the per-node Python loop of the original generator dominated sweep
setup; the vectorized form draws every random stream as a ``(nodes, M)``
block.  The original per-node-loop generator is kept verbatim as
:func:`app_trace_loop` — it is the distribution reference for
:func:`app_trace` (same access-pattern model, *different* PCG64 draw
order, so arrays differ but region/locality statistics match) and it
reproduces the exact (cfg, trace) combinations catalogued in ROADMAP
(e.g. the 16x16/matmul/seed-0/refs=20 protocol livelock).

Every generator here is registered as a :class:`~.base.TrafficGen`:
each app name, ``random``, and the ``loop`` reference family dispatch
through the shared :func:`~.base.resolve` grammar.  Moving these
functions out of ``repro.core.trace`` changed NOTHING bit-wise — the
golden digests in ``tests/test_workloads.py`` pin every output.
"""
from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from ..config import SimConfig
from .base import Param, TrafficGen, register

__all__ = ["TRACE_APPS", "app_trace", "app_trace_loop", "random_trace",
           "from_model_schedule"]

#: node-slab size for vectorized synthesis; fixed so the generated trace is
#: a pure function of (cfg, app, refs, seed), never of how slabs divide n.
_SLAB = 8192


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(np.random.PCG64(seed))


# ---------------------------------------------------------------------------
# Application models.  Each is characterized by:
#   stride         dominant access stride in bytes
#   p_shared       probability an access lands in the globally shared region
#   p_local        probability an access re-touches the node's hot set
#   hot_blocks     size of the node's hot set (in L2 blocks)
#   p_neighbour    probability of touching a mesh-neighbour's private region
#                  (stencil-style sharing)
# Values chosen to mimic the qualitative traffic mix of the SPEC-OMP codes
# the paper uses (matmul: heavy shared-B reuse; mgrid: stencil; equake:
# irregular sparse; wupwise: long strides; apsi: mixed).
# ---------------------------------------------------------------------------
TRACE_APPS = {
    "matmul": dict(stride=8, p_shared=0.45, p_local=0.35, hot_blocks=8, p_neighbour=0.05),
    "apsi": dict(stride=16, p_shared=0.20, p_local=0.50, hot_blocks=16, p_neighbour=0.10),
    "mgrid": dict(stride=8, p_shared=0.10, p_local=0.45, hot_blocks=12, p_neighbour=0.30),
    "wupwise": dict(stride=64, p_shared=0.25, p_local=0.40, hot_blocks=8, p_neighbour=0.10),
    "equake": dict(stride=4, p_shared=0.30, p_local=0.25, hot_blocks=24, p_neighbour=0.10),
}


def _app_seed(app: str, seed: int) -> int:
    stable = sum(ord(ch) * (i + 1) for i, ch in enumerate(app)) % 65536
    return seed * 1_000_003 + stable


def _region_layout(cfg: SimConfig):
    addr_space = 1 << cfg.addr_bits
    blk = cfg.cache.l2_block
    shared_hi = addr_space // 4
    priv_size = max(blk * 4, (addr_space - shared_hi) // cfg.num_nodes)
    return addr_space, blk, shared_hi, priv_size


def _neighbour_table(cfg: SimConfig, nodes: np.ndarray):
    """(len(nodes), 4) neighbour node ids (repeat-padded) + counts."""
    r, c = nodes // cfg.cols, nodes % cfg.cols
    cand = np.stack([
        np.where(r > 0, nodes - cfg.cols, -1),
        np.where(r < cfg.rows - 1, nodes + cfg.cols, -1),
        np.where(c > 0, nodes - 1, -1),
        np.where(c < cfg.cols - 1, nodes + 1, -1),
    ], axis=1)
    # compact valid neighbours to the front (stable order: up, down, left,
    # right — the same enumeration order as the loop reference)
    order = np.argsort(cand < 0, axis=1, kind="stable")
    cand = np.take_along_axis(cand, order, axis=1)
    count = (cand >= 0).sum(axis=1)
    # pad with the first neighbour so any index is safe (never selected:
    # picks are drawn modulo count)
    cand = np.where(cand < 0, cand[:, :1], cand)
    return cand, count


def app_trace(cfg: SimConfig, app: str, refs_per_core: int = 200, seed: int = 0) -> np.ndarray:
    """Representative trace for one of the paper's five applications.

    Vectorized synthesis: all randomness is drawn as ``(slab, M)`` blocks
    (one slab = up to ``_SLAB`` nodes), so generation is O(numpy ops), not
    O(n*M) Python iterations.  Draw order differs from the historical
    per-node loop (:func:`app_trace_loop`), so addresses differ draw-by-draw
    while the access-pattern *distribution* (region mix, hot-set reuse,
    stride behaviour) is identical — see ``tests/test_trace_vec.py``.
    """
    if app not in TRACE_APPS:
        raise ValueError(f"unknown app {app!r}; choose from {sorted(TRACE_APPS)}")
    p = TRACE_APPS[app]
    n, m = cfg.num_nodes, refs_per_core
    addr_space, blk, shared_hi, priv_size = _region_layout(cfg)
    priv_blocks = max(1, priv_size // blk)
    n_shared_blocks = max(1, shared_hi // blk)

    # bounded zipf(1.6) over the shared blocks by inverse CDF: one uniform
    # draw + searchsorted instead of numpy's rejection sampler.  The loop
    # reference draws unbounded zipf then wraps modulo n_shared_blocks; the
    # wrap moves < 1% of the mass at realistic block counts, so the two are
    # distribution-equivalent (asserted by tests/test_trace_vec.py).
    zcdf = np.cumsum(np.arange(1, n_shared_blocks + 1, dtype=np.float64)
                     ** -1.6)
    zcdf /= zcdf[-1]

    # int32 arithmetic end-to-end (addresses are bounded by
    # shared_hi + n*priv_size + priv_size): at 13M samples per 256x256
    # trace the generator is memory-bandwidth bound, so halving the
    # element width matters.  Fall back to int64 for astronomically
    # large meshes.
    top = shared_hi + (n + 1) * priv_size
    idt = np.int32 if top < 2**31 else np.int64
    t_local = p["p_shared"] + p["p_local"]
    t_nb = t_local + p["p_neighbour"]

    out = np.empty((n, m), dtype=np.int32)

    def fill_slab(slab_index: int) -> None:
        # per-slab generator derived from (app, seed, slab): slabs are
        # independent streams, so synthesis parallelizes over host threads
        # (numpy releases the GIL in the fill/searchsorted/cumsum kernels)
        # while staying a pure function of (cfg, app, refs, seed).
        g = np.random.default_rng(np.random.PCG64(
            np.random.SeedSequence([_app_seed(app, seed), slab_index])))
        lo = slab_index * _SLAB
        nodes = np.arange(lo, min(lo + _SLAB, n), dtype=idt)
        ns = len(nodes)
        base = (shared_hi + nodes * priv_size).astype(idt)

        hot = base[:, None] + g.integers(
            0, priv_blocks, (ns, p["hot_blocks"]), dtype=idt) * blk
        kinds = g.random((ns, m), dtype=np.float32)
        hot_idx = g.integers(0, p["hot_blocks"], (ns, m), dtype=np.int32)
        # uniform over each node's own neighbour count (2..4): scale one
        # uniform draw by the count — a modulo of a fixed-range draw would
        # bias the first neighbour on 3-neighbour border nodes
        nb_u = g.random((ns, m), dtype=np.float32)
        nb_block = g.integers(0, priv_blocks, (ns, m), dtype=idt)

        # default: the strided-cursor branch (cursor advances only on
        # strided references: a cumulative count, not a sequential loop)
        is_else = kinds >= t_nb
        strided = np.cumsum(is_else, axis=1, dtype=idt) * p["stride"]
        a = base[:, None] + strided % priv_size

        shared_m = kinds < p["p_shared"]
        local_m = (kinds >= p["p_shared"]) & (kinds < t_local)
        nb_m = (kinds >= t_local) & ~is_else & ~local_m

        # shared branch: draw exactly the uniforms it needs (the count is
        # a pure function of `kinds`, so generation stays deterministic)
        zu = g.random(int(shared_m.sum()), dtype=np.float32)
        zb = (np.searchsorted(zcdf, zu).astype(idt) + 1) % n_shared_blocks
        a[shared_m] = zb * blk

        a_local = np.take_along_axis(hot, hot_idx.astype(idt), axis=1)
        a[local_m] = a_local[local_m]

        nb_table, nb_count = _neighbour_table(cfg, nodes)
        nb_pick = (nb_u * nb_count[:, None]).astype(idt)
        nb = np.take_along_axis(nb_table.astype(idt), nb_pick, axis=1)
        a_nb = shared_hi + nb * priv_size + nb_block * blk
        a[nb_m] = a_nb[nb_m]

        out[lo:lo + ns] = a % addr_space

    n_slabs = -(-n // _SLAB)
    if n_slabs == 1:
        fill_slab(0)
    else:
        workers = min(n_slabs, os.cpu_count() or 1)
        with ThreadPoolExecutor(workers) as ex:
            list(ex.map(fill_slab, range(n_slabs)))
    return out


def app_trace_loop(cfg: SimConfig, app: str, refs_per_core: int = 200, seed: int = 0) -> np.ndarray:
    """Historical per-node-loop generator (the project's original trace
    source), kept verbatim: the distribution reference for the vectorized
    :func:`app_trace` and the exact reproducer for trace-dependent protocol
    pathologies catalogued in ROADMAP.  O(n*M) Python iterations — do not
    use for large meshes."""
    if app not in TRACE_APPS:
        raise ValueError(f"unknown app {app!r}; choose from {sorted(TRACE_APPS)}")
    p = TRACE_APPS[app]
    n = cfg.num_nodes
    g = _rng(_app_seed(app, seed))
    addr_space, blk, shared_hi, priv_size = _region_layout(cfg)

    out = np.full((n, refs_per_core), -1, dtype=np.int64)
    for node in range(n):
        base = shared_hi + node * priv_size
        r, c = divmod(node, cfg.cols)
        neighbours = [nr * cfg.cols + nc
                      for nr, nc in ((r - 1, c), (r + 1, c), (r, c - 1), (r, c + 1))
                      if 0 <= nr < cfg.rows and 0 <= nc < cfg.cols]
        hot = base + (g.integers(0, max(1, priv_size // blk), p["hot_blocks"]) * blk)
        cursor = base
        kinds = g.random(refs_per_core)
        for i in range(refs_per_core):
            k = kinds[i]
            if k < p["p_shared"]:
                # shared region, zipf-ish: few very hot shared blocks
                zb = int(g.zipf(1.6)) % max(1, shared_hi // blk)
                a = zb * blk
            elif k < p["p_shared"] + p["p_local"]:
                a = int(hot[g.integers(0, len(hot))])
            elif k < p["p_shared"] + p["p_local"] + p["p_neighbour"] and neighbours:
                nb = neighbours[int(g.integers(0, len(neighbours)))]
                a = shared_hi + nb * priv_size + int(g.integers(0, priv_size // blk)) * blk
            else:
                cursor = base + (cursor - base + p["stride"]) % priv_size
                a = cursor
            out[node, i] = a % addr_space
    return out.astype(np.int32)


def random_trace(cfg: SimConfig, refs_per_core: int = 200, seed: int = 0) -> np.ndarray:
    """Uniform-random traffic (the paper's synthetic injector)."""
    g = _rng(seed)
    addr_space = 1 << cfg.addr_bits
    a = g.integers(0, addr_space, size=(cfg.num_nodes, refs_per_core), dtype=np.int64)
    # align to word
    return ((a >> 2) << 2).astype(np.int32)


def from_model_schedule(
    cfg: SimConfig,
    layer_params_bytes: int,
    d_model: int,
    n_layers: int,
    refs_per_core: int = 200,
    seed: int = 0,
) -> np.ndarray:
    """Derive an LCMP trace from an LM layer schedule.

    Nodes are tiled over (layer-shard, token-shard): node ``i`` repeatedly
    streams its weight shard (private, strided) and the activation blocks it
    exchanges with its layer neighbours (shared).  This replaces the paper's
    Multi2sim front-end, which could not scale past ~100 cores.

    Vectorized, bit-identical to the original per-node loop: the reference
    pattern is 6 strided weight-block reads then one random activation
    touch, so the only random draws are the activation block indices —
    numpy's bounded-integer sampling consumes the PCG64 stream identically
    whether drawn one scalar at a time or as one ``(n, k)`` block.
    """
    g = _rng(seed)
    n = cfg.num_nodes
    addr_space = 1 << cfg.addr_bits
    blk = cfg.cache.l2_block
    w_region = addr_space // 2
    act_region = addr_space - w_region

    shard = max(blk * 8, min(layer_params_bytes // max(1, n // n_layers), w_region // n))
    act_blocks = max(1, (d_model * 2) // blk)  # one bf16 activation vector

    nodes = np.arange(n, dtype=np.int64)
    layer = nodes % n_layers
    wbase = (nodes * shard) % max(blk, w_region - shard)
    abase = w_region + (layer * act_blocks * blk) % max(blk, act_region - act_blocks * blk)

    i = np.arange(refs_per_core, dtype=np.int64)
    is_act = (i % 7) == 6                          # 6 weight reads, 1 act touch
    n_act = int(is_act.sum())
    act_draw = g.integers(0, act_blocks, size=(n, n_act))

    out = np.empty((n, refs_per_core), dtype=np.int64)
    w_addr = wbase[:, None] + (i[None, :] * blk) % shard
    out[:] = w_addr
    if n_act:
        out[:, is_act] = abase[:, None] + act_draw * blk
    return (out % addr_space).astype(np.int32)


# ---------------------------------------------------------------------------
# Registration: the app models, the uniform injector, and the per-node-loop
# reference family all dispatch through the shared registry grammar.
# ---------------------------------------------------------------------------

_APP_HELP = {
    "matmul": "dense matmul: heavy shared-B reuse (zipf shared blocks)",
    "apsi": "mixed locality (SPEC-OMP apsi-like traffic mix)",
    "mgrid": "stencil: strong mesh-neighbour sharing",
    "wupwise": "long strided streams, moderate sharing",
    "equake": "irregular sparse accesses, large hot set",
}

for _app in TRACE_APPS:
    register(TrafficGen(
        name=_app, kind="app", help=_APP_HELP[_app],
        fn=(lambda cfg, refs, seed, _a=_app: app_trace(cfg, _a, refs, seed))))

register(TrafficGen(
    name="random", kind="injector",
    help="uniform-random addresses over the whole space (the paper's "
         "synthetic injector)",
    fn=lambda cfg, refs, seed: random_trace(cfg, refs, seed)))

register(TrafficGen(
    name="loop", kind="reference",
    help="historical per-node-loop app generator — exact reproducer of "
         "trace-dependent pathologies (e.g. loop:matmul, the former "
         "16x16/seed-0/refs-20 S14 wedge); O(n*M) Python, small meshes only",
    params={"app": Param("matmul", str, "which application model",
                         choices=tuple(TRACE_APPS))},
    positional=("app",),
    fn=lambda cfg, refs, seed, app="matmul":
        app_trace_loop(cfg, app, refs, seed)))
