"""Pluggable workload layer: every trace source behind one registry.

Importing this package registers every built-in traffic generator:

* the five representative **app** models (``matmul``, ``apsi``,
  ``mgrid``, ``wupwise``, ``equake``) — :mod:`.apps`;
* the uniform **injector** ``random`` — :mod:`.apps`;
* the per-node-loop **reference** family ``loop`` (``loop:matmul``
  spells the historical generator) — :mod:`.apps`;
* the synthetic NoC **patterns** ``transpose`` / ``bitcomp`` /
  ``hotspot`` / ``tornado`` / ``neighbor``, parameterized by injection
  rate and hot-node fraction — :mod:`.patterns`.

One grammar everywhere (``name`` or ``name:key=val,...`` — see
:mod:`.base`): :func:`resolve_trace`, :func:`stacked_traces`, manifests,
``--app``, the zoo and the generated CLI docs all dispatch through the
same registry, so registering a generator is the whole job of adding a
scenario source.  ``repro.core.trace`` remains as a thin back-compat
shim over this package.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..config import SimConfig
from .base import (Param, TrafficGen, gen_names, get_gen, parse_source,
                   register, resolve, source_help, source_summary,
                   valid_source)
from .apps import (TRACE_APPS, app_trace, app_trace_loop,
                   from_model_schedule, random_trace)
from .patterns import PATTERN_NAMES, dst_map, pattern_trace

__all__ = [
    "Param", "TrafficGen", "register", "get_gen", "gen_names",
    "parse_source", "valid_source", "source_help", "source_summary",
    "resolve_trace", "valid_app", "stacked_traces",
    "TRACE_APPS", "PATTERN_NAMES", "app_trace", "app_trace_loop",
    "random_trace", "from_model_schedule", "pattern_trace", "dst_map",
]


def resolve_trace(cfg: SimConfig, app: str, refs_per_core: int,
                  seed: int) -> np.ndarray:
    """Trace-source dispatch shared by every scenario consumer.

    ``app`` is any registered source spec (``name`` or
    ``name:key=val,...``): an app model, ``random``, ``loop:<app>``, or
    a synthetic pattern like ``hotspot:frac=0.8,hot=2`` — see
    :func:`source_summary` for the live registry.  ``cfg``,
    ``refs_per_core`` and ``seed`` are forwarded to the generator."""
    return resolve(cfg, app, refs_per_core, seed)


def valid_app(app: str) -> bool:
    """Is ``app`` a source spec :func:`resolve_trace` accepts?  Alias of
    :func:`valid_source` — validation and dispatch share one parser, so
    the two can never disagree."""
    return valid_source(app)


def stacked_traces(cfg: SimConfig, specs, default_refs: int = 200) -> np.ndarray:
    """Stack per-scenario traces into one ``(B, num_nodes, M)`` block for
    the batched sweep engine (:mod:`repro.core.sweep`).

    ``specs`` is an iterable of ``(app, seed)`` or ``(app, seed,
    refs_per_core)`` tuples, where ``app`` is any :func:`resolve_trace`
    source spec.  Scenarios with fewer references are right-padded with
    ``-1`` — the trace-exhaustion sentinel — which is semantically
    identical to running them unpadded, so scenarios of different lengths
    can share one batch.
    """
    mats = []
    for sp in specs:
        app, seed = sp[0], sp[1]
        refs = sp[2] if len(sp) > 2 else default_refs
        mats.append(resolve_trace(cfg, app, refs, seed))
    if not mats:
        raise ValueError("stacked_traces needs at least one scenario")
    m = max(t.shape[1] for t in mats)
    out = np.full((len(mats), cfg.num_nodes, m), -1, np.int32)
    for b, t in enumerate(mats):
        out[b, :, : t.shape[1]] = t
    return out
