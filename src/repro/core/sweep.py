"""Batched multi-scenario sweep engine: B simulations, one XLA program.

The paper's headline metric is simulation *throughput*, and every real
evaluation of a deflection network sweeps scenarios — applications,
injection seeds, policy knobs.  ``run_sweep`` vmaps the fused
``cycle_step``/``while_loop`` driver over a leading scenario axis so B
independent simulations of the same mesh shape execute as ONE compiled
program: one trace/compile and one dispatched device loop instead of B
recompile-and-dispatch round trips.  Per-scenario termination masks
freeze early finishers bit-identically to a solo :func:`repro.core.sim.run`
(a frozen scenario undergoes exactly the cycle steps its solo while loop
would have), so mixed-length scenarios coexist in one batch.

What may vary per scenario:
  * the workload — source spec / seed / refs-per-core (stacked,
    ``-1``-padded traces, see
    :func:`repro.core.workloads.stacked_traces`);
  * traced policy knobs carried in state (``SimState.knob_*``):
    migration on/off, migration threshold, centralized vs distributed
    directory.

What must be shared (it changes array shapes or compiled structure):
mesh size, cache geometry, latencies, ``dir_layout``, queue/ROB depths —
these come from the sweep-wide ``SweepSpec.cfg``.  Mixed-shape scenario
lists are handled one level up: :mod:`repro.core.engine` buckets them by
structural config and runs one sweep (one compiled program) per bucket.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from .config import SimConfig
from .sim import _run_jit, check_cycle_cap, run, stats_list
from .state import SimState, init_state
from .workloads import stacked_traces

__all__ = ["ScenarioSpec", "SweepSpec", "run_sweep", "run_sequential",
           "scenario_device_count"]


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One scenario of a sweep: a workload plus optional policy knobs.

    ``None`` knobs inherit the sweep-wide :class:`SimConfig` value."""

    app: str = "matmul"        # source spec (see workloads.resolve_trace)
    seed: int = 0
    refs_per_core: int = 200
    migration_enabled: Optional[bool] = None
    migrate_threshold: Optional[int] = None
    centralized_directory: Optional[bool] = None
    eject_age_threshold: Optional[int] = None

    def resolve_cfg(self, cfg: SimConfig) -> SimConfig:
        """This scenario's effective SimConfig (the sequential path runs
        a solo simulation with exactly this config)."""
        kw = {}
        if self.migration_enabled is not None:
            kw["migration_enabled"] = self.migration_enabled
        if self.migrate_threshold is not None:
            kw["migrate_threshold"] = self.migrate_threshold
        if self.centralized_directory is not None:
            kw["centralized_directory"] = self.centralized_directory
        if self.eject_age_threshold is not None:
            kw["eject_age_threshold"] = self.eject_age_threshold
        return dataclasses.replace(cfg, **kw) if kw else cfg


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A batch of scenarios over one shared mesh/cache/latency config.

    Attributes:
        cfg: the sweep-wide structural :class:`SimConfig` — everything
            that changes array shapes or compiled structure (mesh size,
            cache geometry, latencies, ``dir_layout``, queue/ROB depths)
            is shared by all scenarios.
        scenarios: B :class:`ScenarioSpec` workloads; each may override
            the traced policy knobs only.

    The stacked workload block is ``(B, num_nodes, M)`` (``-1``-padded
    to the longest trace, see :meth:`traces`); consumers are
    :func:`run_sweep` (vmapped) and
    :func:`repro.core.sharded.run_composed` (batched shard_map)."""

    cfg: SimConfig
    scenarios: Tuple[ScenarioSpec, ...]

    @classmethod
    def cross(cls, cfg: SimConfig, apps: Sequence[str],
              seeds: Sequence[int], refs_per_core: int = 200) -> "SweepSpec":
        """Cross-product sweep: every app with every seed."""
        return cls(cfg, tuple(ScenarioSpec(a, int(s), refs_per_core)
                              for a in apps for s in seeds))

    @property
    def size(self) -> int:
        return len(self.scenarios)

    def validate(self) -> None:
        if not self.scenarios:
            raise ValueError("empty sweep")
        for sc in self.scenarios:
            rc = sc.resolve_cfg(self.cfg)
            rc.validate()
            if self.cfg.dir_layout == "home" and rc.centralized_directory:
                raise ValueError(
                    "home-sharded directory layout cannot batch a "
                    f"centralized-directory scenario: {sc}")

    @functools.cached_property
    def _traces(self) -> np.ndarray:
        return stacked_traces(
            self.cfg,
            [(sc.app, sc.seed, sc.refs_per_core) for sc in self.scenarios])

    def traces(self) -> np.ndarray:
        """Stacked ``(B, num_nodes, M)`` workload block (synthesized once
        per spec — trace generation is python-loop setup cost, not part
        of the engine)."""
        return self._traces

    def knob_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                   np.ndarray]:
        """Per-scenario (migration, threshold, centralized, eject-age)
        int32 vectors — one entry per traced ``SimState.knob_*`` leaf."""
        res = [sc.resolve_cfg(self.cfg) for sc in self.scenarios]
        mig = np.asarray([int(c.migration_enabled) for c in res], np.int32)
        thr = np.asarray([c.migrate_threshold for c in res], np.int32)
        cen = np.asarray([int(c.centralized_directory) for c in res], np.int32)
        eja = np.asarray([c.eject_age_threshold for c in res], np.int32)
        return mig, thr, cen, eja


def scenario_device_count(batch: int, ndev: int) -> int:
    """Devices the scenario axis uses.  :func:`run_sweep` pads an
    indivisible batch up to a multiple of this count (with copies of the
    last scenario, dropped from the results), so every device carries
    ``ceil(batch / n)`` scenarios; the planner's cost model in
    :mod:`repro.core.engine` relies on the same number."""
    return max(min(ndev, batch), 1)


def _maybe_shard(s: SimState, batch: int) -> SimState:
    """Shard the scenario axis over the local devices.

    The batch is embarrassingly parallel (the only cross-scenario ops are
    tiny boolean any-reductions in the loop conditions), so placing
    B/n scenarios on each of n devices runs them concurrently inside the
    single compiled program.  On CPU, expose the cores as devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=<n>`` before
    importing jax; with one device this is a no-op and results are
    bit-identical either way (integer ops, no cross-scenario math).
    """
    devs = jax.local_devices()
    n = scenario_device_count(batch, len(devs))
    while n > 1 and batch % n:      # defensive: unpadded direct callers
        n -= 1
    if n <= 1:
        return s
    mesh = Mesh(np.asarray(devs[:n]), ("scenario",))
    sh = NamedSharding(mesh, PartitionSpec("scenario"))
    return jax.tree.map(lambda x: jax.device_put(x, sh), s)


def run_sweep(spec: SweepSpec, max_cycles: Optional[int] = None,
              chunk: int = 1) -> List[Dict[str, int]]:
    """Run all scenarios of ``spec`` in one jitted batched loop.

    Args:
        spec: the sweep — B workloads plus traced knobs over one
            structural config.  The scenario axis is sharded over the
            local devices; an indivisible batch is padded with copies of
            the last scenario (dropped from the results).
        max_cycles: per-scenario cycle cap (default ``cfg.max_cycles``).
        chunk: simulated cycles per in-graph termination check (larger =
            fewer loop-condition evaluations, coarser early exit; the
            per-cycle tail keeps the cap exact either way).

    Returns: one stats dict per scenario, in scenario order, bit-identical
    to what a solo ``run(sc.resolve_cfg(cfg), trace)`` would produce.
    """
    spec.validate()
    cfg = spec.cfg
    check_cycle_cap(cfg, max_cycles)
    traces = spec.traces()
    mig, thr, cen, eja = spec.knob_arrays()
    # pad an indivisible batch up to a multiple of the device count with
    # copies of the last scenario (dropped from the results): 5 scenarios
    # on 4 devices would otherwise collapse to a single device.  Copies
    # finish the same cycle as their original, so padding costs no
    # wall-clock, and scenarios are independent, so results are unchanged.
    pad = (-spec.size) % scenario_device_count(spec.size,
                                               len(jax.local_devices()))
    if pad:
        traces = np.concatenate([traces, np.repeat(traces[-1:], pad, 0)])
        mig, thr, cen, eja = (np.concatenate([a, np.repeat(a[-1:], pad, 0)])
                              for a in (mig, thr, cen, eja))
    s = init_state(cfg, traces)
    s = s._replace(knob_mig=jnp.asarray(mig),
                   knob_mig_thr=jnp.asarray(thr),
                   knob_central=jnp.asarray(cen),
                   knob_ej_age=jnp.asarray(eja))
    s = _maybe_shard(s, spec.size + pad)
    s, aux = _run_jit(s, cfg,
                      jnp.asarray(max_cycles or cfg.max_cycles, jnp.int32),
                      chunk)
    return stats_list(s, aux)[:spec.size]


def run_sequential(spec: SweepSpec, max_cycles: Optional[int] = None,
                   chunk: int = 1) -> List[Dict[str, int]]:
    """Reference path: one solo ``run()`` per scenario (B device loop
    dispatches; B compiles when knobs differ).  Used by the throughput
    benchmark and the bit-exactness tests."""
    spec.validate()
    traces = spec.traces()
    return [run(sc.resolve_cfg(spec.cfg), traces[b], max_cycles, chunk)
            for b, sc in enumerate(spec.scenarios)]
