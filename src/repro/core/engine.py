"""Unified execution-plan layer: one engine behind run / sweep / sharded.

A *plan* turns a heterogeneous list of :class:`Scenario` — any mix of mesh
shapes, apps, seeds and policy knobs — into the minimal set of device
programs:

1. **Bucket** scenarios by structural configuration: everything that
   changes array shapes or compiled structure (mesh shape, cache geometry,
   latencies, directory layout, queue/ROB depths, cycle budget).  Policy
   knobs (migration on/off, migrate threshold, centralized vs distributed
   directory) are *traced* per-scenario state in the batched driver, so
   they never split a bucket — scenarios that differ only in workload or
   knobs share ONE compiled program.
2. **Choose a backend per bucket** with a cost model over
   ``(batch, nodes, devices)``:

   * ``sweep`` — the vmapped batched driver (:mod:`repro.core.sweep`),
     scenario axis sharded over local devices.  A batch of one is the
     classic solo run; both ride the same compiled loop.
   * ``sharded`` — the 2-D spatial ``shard_map`` decomposition
     (:mod:`repro.core.sharded`), for a single huge scenario whose node
     grid is worth splitting across devices.  The device grid is factored
     automatically (:func:`choose_tiling`); on one device, or when no
     factoring divides the mesh, the plan falls back to ``sweep`` instead
     of asserting.
   * ``composed`` — both axes at once: the bucket compiles to a batched
     ``shard_map`` program over a 3-D ``(scenario, rows, cols)`` device
     mesh (:func:`repro.core.sharded.run_composed`) — vmap over the
     scenario axis *inside* the spatially sharded step, halo exchange
     unchanged per tile.  The device count is factored into
     ``(batch_shards, row_tiles, col_tiles)`` by :func:`choose_grid`;
     degeneracies fall out cleanly (one device == solo, ``batch_shards
     == 1`` == spatial, an indivisible scenario axis pads with copies of
     the last scenario like :func:`repro.core.sweep.run_sweep`).

3. **Execute** buckets sequentially (each is one compiled program) and
   reassemble per-scenario statistics in the original scenario order —
   bit-identical to running each scenario through a solo
   :func:`repro.core.sim.run`.

Cost-model constants are CPU-calibrated defaults; run
``benchmarks/calibrate_cost_model.py`` on the actual host to measure them
and point ``REPRO_COST_MODEL`` (or :func:`load_cost_constants`) at the
emitted file.

Manifests: :func:`load_manifest` accepts a JSON object/list (or a path to
one), or the compact CLI grammar ``ROWSxCOLS[:APP][:SEED[:REFS]]`` joined
with ``;`` or ``,`` — APP is any workload-registry source spec
(``matmul``, ``loop:matmul``, ``hotspot:frac=0.8,hot=2``, ...)::

    {"base": {"addr_bits": 16, "centralized_directory": false},
     "scenarios": [
       {"rows": 8,  "cols": 8,  "app": "matmul", "seed": 0, "refs_per_core": 50},
       {"rows": 16, "cols": 16, "app": "equake", "seed": 1,
        "migration_enabled": false}]}

This layer is the architectural precondition for the ROADMAP's
scenario x row x col device-mesh composition: scenario-parallel and
space-parallel execution are now two backends behind one planner.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .config import CacheConfig, SimConfig
from .workloads import source_summary, valid_source

__all__ = [
    "Scenario", "Bucket", "ExecutionPlan", "make_scenario", "bucket_key",
    "choose_tiling", "choose_grid", "backend_cost", "choose_backend",
    "compile_plan", "execute_plan", "plan_and_run", "load_manifest",
    "expose_host_devices", "CostConstants", "cost_constants",
    "set_cost_constants", "load_cost_constants", "save_cost_constants",
    "parse_mem_budget", "plan_state_bytes",
]


def expose_host_devices() -> None:
    """Expose CPU cores as XLA host devices so the sweep backend can shard
    the scenario axis.  Must run before the first jax import; a no-op when
    the flag is already set (so explicit user pins win) or jax is loaded."""
    import sys
    if "jax" not in sys.modules \
            and "--xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={os.cpu_count()}")

#: SimConfig fields carried as traced per-scenario state by the batched
#: driver (SimState.knob_*) — these never force a new bucket/compile.
#: ``eject_age_threshold`` is traced (a per-flit comparison constant);
#: ``pc_depth`` is NOT — it sizes the pending-completion queue array, so
#: it is structural and splits buckets like every other shape knob.
KNOB_FIELDS = ("migration_enabled", "migrate_threshold",
               "centralized_directory", "eject_age_threshold")
_KNOB_NORM = dict(migration_enabled=True, migrate_threshold=3,
                  centralized_directory=False, eject_age_threshold=0)

@dataclasses.dataclass(frozen=True)
class CostConstants:
    """Cost-model constants: driver work per simulated cycle, node-units.

    The defaults are CPU-calibrated guesses; ``benchmarks/
    calibrate_cost_model.py`` measures them on the actual host and emits
    a JSON file this module loads (:func:`load_cost_constants`, or
    automatically from the path in ``$REPRO_COST_MODEL`` at import).

    Attributes:
        halo_overhead: relative per-node cost of a sharded tile vs the
            dense single-device step (halo ppermutes + the termination
            psum), multiplying the tile's bandwidth term.
        shard_fixed: fixed per-cycle cost of the spatial backend's
            collectives (latency-bound, independent of tile size) —
            keeps small meshes off ``shard_map``.
        batch_fixed: the composed backend's incremental fixed per-cycle
            cost for each *additional* local scenario vmapped through a
            spatially-sharded tile step: the halo slabs still ride one
            ppermute per direction, but every extra scenario adds its
            own slab payload to those fixed-latency collectives (and a
            lane to the per-scenario termination psum).  This is what
            makes the planner prefer sharding the scenario axis (which
            needs no collectives) over deeper spatial tiling when the
            devices could do either.
    """

    halo_overhead: float = 1.25
    shard_fixed: float = 4096.0
    batch_fixed: float = 1024.0


_COST = CostConstants()


def cost_constants() -> CostConstants:
    """The cost-model constants currently in force."""
    return _COST


def set_cost_constants(c: CostConstants) -> None:
    """Install ``c`` as the constants used by :func:`backend_cost` (and
    therefore every subsequent :func:`compile_plan`)."""
    global _COST
    _COST = c


def load_cost_constants(path: str) -> CostConstants:
    """Load calibrated constants from a JSON file (as emitted by
    ``benchmarks/calibrate_cost_model.py``) and install them.

    The file must hold an object with ``halo_overhead`` /
    ``shard_fixed`` / ``batch_fixed`` keys; anything else (calibration
    metadata) is ignored.  Returns the installed :class:`CostConstants`.
    """
    with open(path) as f:
        obj = json.load(f)
    c = CostConstants(**{k: float(obj[k])
                         for k in ("halo_overhead", "shard_fixed",
                                   "batch_fixed") if k in obj})
    set_cost_constants(c)
    return c


def save_cost_constants(path: str, c: CostConstants,
                        meta: Optional[Dict] = None) -> None:
    """Write ``c`` (plus optional calibration ``meta``) as a JSON file
    round-trippable through :func:`load_cost_constants`."""
    obj = dataclasses.asdict(c)
    if meta:
        obj["meta"] = meta
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
        f.write("\n")


if os.environ.get("REPRO_COST_MODEL"):
    load_cost_constants(os.environ["REPRO_COST_MODEL"])


# ---------------------------------------------------------------------------
# Memory model
# ---------------------------------------------------------------------------

_MEM_SUFFIX = {"k": 1 << 10, "m": 1 << 20, "g": 1 << 30, "t": 1 << 40}


def parse_mem_budget(text: Optional[str]) -> Optional[int]:
    """Parse a per-device memory budget: a byte count, optionally with a
    binary suffix (``512M``, ``4G``, ``1.5g``).  ``None``/empty → no
    budget."""
    if text is None or not str(text).strip():
        return None
    t = str(text).strip().lower().rstrip("b").rstrip("i")
    mul = 1
    if t and t[-1] in _MEM_SUFFIX:
        mul = _MEM_SUFFIX[t[-1]]
        t = t[:-1]
    try:
        val = int(float(t) * mul)
    except ValueError:
        raise ValueError(f"bad memory budget {text!r}; expected bytes with "
                         "an optional K/M/G/T suffix, e.g. '512M'") from None
    if val <= 0:
        raise ValueError(f"memory budget must be positive, got {text!r}")
    return val


def plan_state_bytes(cfg: SimConfig, batch: int, backend: str,
                     grid: Tuple[int, int, int], ndev: int,
                     trace_len: int = 200) -> int:
    """Estimated *resident* :class:`SimState` bytes per device for one
    bucket under ``backend``/``grid``.

    This counts the persistent simulation state only (at ``cfg``'s
    ``state_dtype_policy``); per-cycle transients and the compiled
    program ride on top, so treat budgets as a floor on what the device
    must hold, not an exact high-water mark.  Donation (the run loops
    update the state in place) is what makes the resident set ~one copy
    rather than two."""
    from .state import state_bytes
    sb = state_bytes(cfg, trace_len=trace_len)
    if backend == "sweep":
        from .sweep import scenario_device_count
        n = scenario_device_count(batch, ndev)
        return -(-batch // n) * sb
    if backend in ("sharded", "composed"):
        nt = grid[-2] * grid[-1]
        local_b = -(-batch // max(grid[0], 1)) if backend == "composed" else 1
        return -(-local_b * sb // max(nt, 1))
    raise ValueError(f"unknown backend {backend!r}")


def _fmt_bytes(n: int) -> str:
    for suf, mul in (("G", 1 << 30), ("M", 1 << 20), ("K", 1 << 10)):
        if n >= mul:
            return f"{n / mul:.1f}{suf}"
    return f"{n}B"


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One unit of work for the planner: a fully-resolved config plus a
    workload.

    Attributes:
        cfg: the scenario's complete :class:`SimConfig`, *including*
            policy knobs — the planner decides what is structural (splits
            compile buckets) and what is traced (rides as
            ``SimState.knob_*`` state).
        app: workload source spec, dispatched through the traffic-
            generator registry (:mod:`repro.core.workloads`): an app
            model (``matmul``/``apsi``/``mgrid``/``wupwise``/``equake``),
            ``random``, ``loop:<app>`` (the historical per-node-loop
            reference generator), or a synthetic NoC pattern with
            optional parameters (``transpose``, ``bitcomp``,
            ``hotspot:frac=0.8,hot=2``, ``tornado``, ``neighbor:rate=0.5``).
        seed: trace-synthesis seed.
        refs_per_core: memory references each core issues; the synthesized
            trace is ``(cfg.num_nodes, refs_per_core)`` int32 addresses.
    """

    cfg: SimConfig
    app: str = "matmul"            # trace source (workloads registry spec)
    seed: int = 0
    refs_per_core: int = 200

    def validate(self) -> None:
        """Raise ``ValueError``/``AssertionError`` on an invalid config,
        unknown app name, or non-positive refs_per_core."""
        self.cfg.validate()
        if not valid_source(self.app):
            # re-parse to surface the specific parse error (unknown
            # generator vs bad parameter) with the registry roll-call
            from .workloads import parse_source
            try:
                parse_source(self.app)
            except ValueError as e:
                raise ValueError(f"bad scenario app: {e}") from None
        if self.refs_per_core < 1:
            raise ValueError("refs_per_core must be >= 1")


def make_scenario(base: SimConfig, rows: Optional[int] = None,
                  cols: Optional[int] = None, app: str = "matmul",
                  seed: int = 0, refs_per_core: int = 200,
                  **overrides) -> Scenario:
    """Scenario constructor: ``base`` config + shape + any SimConfig
    overrides (structural or knob — the planner sorts out which).

    Args:
        base: the config every non-overridden field comes from.
        rows: mesh rows override (default: ``base.rows``).
        cols: mesh columns override (default: ``base.cols``).
        app: workload source spec (registry grammar).
        seed: trace-synthesis seed.
        refs_per_core: memory references per core.
        **overrides: any further SimConfig field overrides.
    """
    kw = dict(overrides)
    if rows is not None:
        kw["rows"] = rows
    if cols is not None:
        kw["cols"] = cols
    cfg = dataclasses.replace(base, **kw) if kw else base
    return Scenario(cfg=cfg, app=app, seed=seed, refs_per_core=refs_per_core)


def bucket_key(cfg: SimConfig) -> SimConfig:
    """Structural identity of a config: the config with every traced knob
    normalized away.  Two scenarios with equal keys share one compiled
    program."""
    return dataclasses.replace(cfg, **_KNOB_NORM)


def choose_tiling(rows: int, cols: int, ndev: int) -> Tuple[int, int]:
    """Factor the device count into a ``(row_tiles, col_tiles)`` grid that
    divides the simulated mesh, using as many devices as possible and
    preferring near-square tilings (halo perimeter ~ rt+ct).  Returns
    ``(1, 1)`` when nothing but a single device fits — the planner then
    falls back to the dense backend instead of asserting."""
    best = (1, 1)
    for d in range(min(ndev, rows * cols), 1, -1):
        cands = [(rt, d // rt) for rt in range(1, d + 1)
                 if d % rt == 0 and rows % rt == 0 and cols % (d // rt) == 0]
        if cands:
            return min(cands, key=lambda t: abs(t[0] - t[1]))
    return best


def backend_cost(backend: str, batch: int, nodes: int, ndev: int,
                 tiles: Union[Tuple[int, int], Tuple[int, int, int]] = (1, 1)
                 ) -> float:
    """Estimated driver work per simulated cycle, in node-units on the
    critical path (lower is better).

    Args:
        backend: ``"sweep"`` | ``"sharded"`` | ``"composed"``.
        batch: scenarios in the bucket.
        nodes: simulated nodes per scenario (``rows * cols``).
        ndev: devices the plan may use.
        tiles: ``(row_tiles, col_tiles)`` for ``sharded``;
            ``(batch_shards, row_tiles, col_tiles)`` for ``composed``
            (a 2-tuple is treated as ``batch_shards = 1``).

    Returns: the estimated cost; ``inf`` for a structurally impossible
    combination (e.g. ``sharded`` with ``batch > 1``)."""
    c = _COST
    if backend == "sweep":
        # deferred import: sweep pulls in jax, which plan compilation with
        # an explicit ndev otherwise never needs
        from .sweep import scenario_device_count
        # run_sweep pads the batch to a multiple of the device count, so
        # wall-clock work is ceil(batch / devices) scenario-steps
        n = scenario_device_count(batch, ndev)
        return nodes * -(-batch // n)
    if backend == "sharded":
        nt = tiles[-2] * tiles[-1]
        if batch != 1 or nt <= 1:
            return float("inf")
        return nodes / nt * c.halo_overhead + c.shard_fixed
    if backend == "composed":
        bs = tiles[0] if len(tiles) == 3 else 1
        nt = tiles[-2] * tiles[-1]
        if nt <= 1 or bs < 1:
            return float("inf")
        # each device carries ceil(batch / batch_shards) scenarios, all
        # vmapped through one tile step; the four halo ppermutes are paid
        # once per cycle (batched slabs), so the bandwidth term scales
        # with the local batch and each extra local scenario adds only
        # its slab payload (batch_fixed) to the fixed collectives
        local_b = -(-batch // min(bs, batch))
        return (local_b * nodes / nt * c.halo_overhead + c.shard_fixed
                + (local_b - 1) * c.batch_fixed)
    raise ValueError(f"unknown backend {backend!r}")


def choose_grid(batch: int, rows: int, cols: int, ndev: int,
                cfg: Optional[SimConfig] = None,
                mem_budget: Optional[int] = None, trace_len: int = 200
                ) -> Tuple[Tuple[int, int, int], float]:
    """Factor ``ndev`` into the cheapest composed ``(batch_shards,
    row_tiles, col_tiles)`` grid for a ``batch``-scenario bucket of
    ``rows x cols`` meshes.

    Every split of the device count between the scenario axis and the
    spatial tiling (``choose_tiling`` on the remainder) is costed with
    :func:`backend_cost`; grids whose spatial part collapses to ``1x1``
    are skipped (that regime belongs to the sweep backend).  With a
    ``mem_budget`` (and ``cfg`` to size the state), grids whose
    per-device resident state exceeds the budget are skipped too — the
    planner re-tiles toward deeper spatial splits that fit.

    Returns: ``(grid, cost)``; ``((1, 1, 1), inf)`` when no composed
    grid is structurally possible (or none fits the budget)."""
    best, best_cost = (1, 1, 1), float("inf")
    nodes = rows * cols
    for bs in range(1, max(min(ndev, batch), 1) + 1):
        rt, ct = choose_tiling(rows, cols, ndev // bs)
        if rt * ct <= 1:
            continue
        grid = (bs, rt, ct)
        if mem_budget is not None and cfg is not None and \
                plan_state_bytes(cfg, batch, "composed", grid, ndev,
                                 trace_len) > mem_budget:
            continue
        cost = backend_cost("composed", batch, nodes, ndev, grid)
        if cost < best_cost:
            best, best_cost = grid, cost
    return best, best_cost


#: 3-D grid meaning per backend: sweep ignores it, sharded uses the
#: spatial part, composed uses all three axes.
_GRID_NONE = (1, 1, 1)


def choose_backend(cfg: SimConfig, batch: int, ndev: int,
                   force: Optional[str] = None,
                   mem_budget: Optional[int] = None, trace_len: int = 200
                   ) -> Tuple[str, Tuple[int, int, int], str]:
    """Pick ``(backend, grid, note)`` for one bucket.

    Args:
        cfg: the bucket's structural config (with ``centralized_directory``
            reflecting whether *any* scenario in the bucket uses it —
            such buckets can never shard spatially).
        batch: scenarios in the bucket.
        ndev: devices available to the plan.
        force: pin the backend (CLI ``--backend``); a forced ``sharded``
            or ``composed`` that is structurally impossible (one device,
            centralized directory, an indivisible mesh, or — for
            ``sharded`` — ``batch > 1``) degrades to ``sweep`` with an
            explanatory note instead of asserting.
        mem_budget: per-device resident-state byte budget.  Candidates
            over budget are dropped (composed re-tiles toward deeper
            spatial splits first); if *no* candidate fits, or a forced
            backend is over budget, ``ValueError`` — the fix is a packed
            ``state_dtype_policy``, more devices, or a bigger budget.
        trace_len: per-core trace length, for sizing the state.

    Returns: the backend name, its ``(batch_shards, row_tiles,
    col_tiles)`` device grid (``(1, 1, 1)`` for sweep), and a short
    explanation when the choice was forced, degraded, cost-driven, or
    shaped by the memory budget."""
    tiles = choose_tiling(cfg.rows, cfg.cols, ndev)
    spatial_ok = not cfg.centralized_directory and tiles != (1, 1)
    grid, c_comp = (choose_grid(batch, cfg.rows, cfg.cols, ndev, cfg=cfg,
                                mem_budget=mem_budget, trace_len=trace_len)
                    if not cfg.centralized_directory
                    else (_GRID_NONE, float("inf")))

    def fits(backend: str, g: Tuple[int, int, int]) -> bool:
        return mem_budget is None or plan_state_bytes(
            cfg, batch, backend, g, ndev, trace_len) <= mem_budget

    def over_budget(backend: str, g: Tuple[int, int, int]) -> ValueError:
        need = plan_state_bytes(cfg, batch, backend, g, ndev, trace_len)
        return ValueError(
            f"{backend} backend needs ~{_fmt_bytes(need)}/device for "
            f"{batch}x{cfg.rows}x{cfg.cols} "
            f"({cfg.state_dtype_policy} state), over the "
            f"{_fmt_bytes(mem_budget)} budget; use state_dtype_policy="
            "'packed', more devices, or a larger budget")

    if force == "sweep":
        if not fits("sweep", _GRID_NONE):
            raise over_budget("sweep", _GRID_NONE)
        return "sweep", _GRID_NONE, "forced"
    if force == "sharded":
        if batch == 1 and spatial_ok:
            if not fits("sharded", (1,) + tiles):
                raise over_budget("sharded", (1,) + tiles)
            return "sharded", (1,) + tiles, "forced"
        why = ("batch > 1" if batch > 1
               else "centralized directory" if cfg.centralized_directory
               else f"no device tiling divides {cfg.rows}x{cfg.cols} "
                    f"over {ndev} device(s)")
        if not fits("sweep", _GRID_NONE):
            raise over_budget("sweep", _GRID_NONE)
        return "sweep", _GRID_NONE, f"sharded unavailable ({why}); fell back"
    if force == "composed":
        if c_comp < float("inf"):
            return "composed", grid, "forced"
        why = ("centralized directory" if cfg.centralized_directory
               else f"no device grid tiles {cfg.rows}x{cfg.cols} over "
                    f"{ndev} device(s)")
        if not fits("sweep", _GRID_NONE):
            raise over_budget("sweep", _GRID_NONE)
        return "sweep", _GRID_NONE, f"composed unavailable ({why}); fell back"
    if force is not None:
        raise ValueError(f"unknown backend {force!r}")
    c_sweep = backend_cost("sweep", batch, cfg.num_nodes, ndev)
    cands = [(c_sweep, "sweep", _GRID_NONE)]
    if batch == 1 and spatial_ok:
        cands.append((backend_cost("sharded", batch, cfg.num_nodes, ndev,
                                   tiles), "sharded", (1,) + tiles))
    if batch > 1:
        # batch == 1 composed degenerates to sharded — already a candidate
        cands.append((c_comp, "composed", grid))
    dropped = [b for c, b, g in cands
               if c < float("inf") and not fits(b, g)]
    cands = [(c, b, g) for c, b, g in cands if fits(b, g)]
    if not cands or min(c for c, _, _ in cands) == float("inf"):
        raise over_budget("sweep", _GRID_NONE)
    cost, backend, grid = min(cands, key=lambda t: t[0])
    note = "" if backend == "sweep" \
        else f"cost {cost:.0f} < sweep {c_sweep:.0f}"
    if dropped:
        over = f"memory budget excluded {'/'.join(dropped)}"
        note = f"{note}; {over}" if note else over
    return backend, grid, note


@dataclasses.dataclass(frozen=True)
class Bucket:
    """Scenarios sharing one structural config → one compiled program.

    Attributes:
        cfg: the structural (knob-normalized) config every scenario in
            the bucket shares.
        scenarios: the bucket's scenarios, in input order.
        indices: each scenario's position in the original plan list.
        backend: ``"sweep"`` | ``"sharded"`` | ``"composed"``.
        grid: the ``(batch_shards, row_tiles, col_tiles)`` device grid —
            ``(1, 1, 1)`` for sweep, ``(1, rt, ct)`` for sharded.
        note: why the planner chose/degraded this backend (may be empty).
        mem_bytes: estimated resident state bytes per device
            (:func:`plan_state_bytes`; 0 when not computed).
    """

    cfg: SimConfig                     # structural (knob-normalized) config
    scenarios: Tuple[Scenario, ...]
    indices: Tuple[int, ...]           # positions in the original list
    backend: str                       # "sweep" | "sharded" | "composed"
    grid: Tuple[int, int, int] = (1, 1, 1)
    note: str = ""
    mem_bytes: int = 0                 # est. resident state bytes / device

    @property
    def batch(self) -> int:
        return len(self.scenarios)

    @property
    def tiles(self) -> Tuple[int, int]:
        """The spatial ``(row_tiles, col_tiles)`` part of :attr:`grid`."""
        return self.grid[1:]

    @property
    def devices_needed(self) -> int:
        """Devices this bucket's program is laid out over."""
        return self.grid[0] * self.grid[1] * self.grid[2]


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A compiled plan: the input scenarios, their buckets (one compiled
    program each) and the device count the plan was costed for."""

    scenarios: Tuple[Scenario, ...]
    buckets: Tuple[Bucket, ...]
    ndev: int
    mem_budget: Optional[int] = None

    def describe(self) -> Dict:
        """JSON-friendly summary: shape/batch/backend/grid per bucket,
        plus each bucket's state-dtype policy and estimated resident
        state bytes per device (and the budget they were planned
        against, when one was set)."""
        return {
            "n_scenarios": len(self.scenarios),
            "n_buckets": len(self.buckets),
            "devices": self.ndev,
            **({"mem_budget": self.mem_budget}
               if self.mem_budget is not None else {}),
            "buckets": [{
                "rows": b.cfg.rows, "cols": b.cfg.cols, "batch": b.batch,
                "backend": b.backend,
                "policy": b.cfg.state_dtype_policy,
                "state_bytes_per_device": b.mem_bytes,
                **({"tiles": list(b.tiles)} if b.backend == "sharded" else {}),
                **({"grid": list(b.grid)} if b.backend == "composed" else {}),
                **({"note": b.note} if b.note else {}),
            } for b in self.buckets],
        }


def compile_plan(scenarios: Sequence[Scenario], ndev: Optional[int] = None,
                 force_backend: Optional[str] = None,
                 mem_budget: Optional[int] = None) -> ExecutionPlan:
    """Bucket scenarios by structural config and choose each bucket's
    backend and device grid.

    Args:
        scenarios: the work list — any mix of mesh shapes, apps, seeds
            and policy knobs.  Scenarios differing only in workload or
            knobs share a bucket (ONE compiled program).
        ndev: device count to cost the plan for; defaults to
            ``len(jax.local_devices())`` (the only reason this function
            may import jax — pass it explicitly for a pure planning
            step).
        force_backend: pin every bucket to ``"sweep"`` / ``"sharded"`` /
            ``"composed"``; impossible pins degrade per bucket with a
            note (see :func:`choose_backend`).
        mem_budget: per-device resident-state byte budget; defaults to
            ``$REPRO_MEM_BUDGET`` (``parse_mem_budget`` grammar, e.g.
            ``512M``).  Buckets that cannot fit under any backend raise
            ``ValueError`` (see :func:`choose_backend`).

    Returns: an :class:`ExecutionPlan`.  Deterministic: bucket order
    follows first appearance in ``scenarios``; per-bucket scenario order
    follows the input order."""
    if not scenarios:
        raise ValueError("empty plan")
    for sc in scenarios:
        sc.validate()
    if ndev is None:
        import jax
        ndev = len(jax.local_devices())
    if mem_budget is None:
        mem_budget = parse_mem_budget(os.environ.get("REPRO_MEM_BUDGET"))

    groups: Dict[SimConfig, List[int]] = {}
    for i, sc in enumerate(scenarios):
        groups.setdefault(bucket_key(sc.cfg), []).append(i)

    buckets = []
    for key, idxs in groups.items():
        scs = tuple(scenarios[i] for i in idxs)
        # the knob check must see the *scenario* configs, not the
        # normalized key: forced-sharded/composed eligibility depends on
        # them (a centralized-directory scenario bars the home-sharded
        # directory layout both spatial backends require)
        any_central = any(sc.cfg.centralized_directory for sc in scs)
        probe = dataclasses.replace(key, centralized_directory=any_central)
        # the batched drivers stack traces padded to the longest, so the
        # footprint is sized by the bucket's largest refs_per_core
        refs = max(sc.refs_per_core for sc in scs)
        backend, grid, note = choose_backend(probe, len(scs), ndev,
                                             force_backend,
                                             mem_budget=mem_budget,
                                             trace_len=refs)
        mem = plan_state_bytes(key, len(scs), backend, grid, ndev, refs)
        buckets.append(Bucket(cfg=key, scenarios=scs, indices=tuple(idxs),
                              backend=backend, grid=grid, note=note,
                              mem_bytes=mem))
    return ExecutionPlan(tuple(scenarios), tuple(buckets), ndev, mem_budget)


def _bucket_sweep_spec(b: Bucket):
    from .sweep import ScenarioSpec, SweepSpec
    return SweepSpec(b.cfg, tuple(
        ScenarioSpec(
            app=sc.app, seed=sc.seed, refs_per_core=sc.refs_per_core,
            migration_enabled=sc.cfg.migration_enabled,
            migrate_threshold=sc.cfg.migrate_threshold,
            centralized_directory=sc.cfg.centralized_directory,
            eject_age_threshold=sc.cfg.eject_age_threshold,
        ) for sc in b.scenarios))


def _run_bucket_sweep(b: Bucket, max_cycles: Optional[int],
                      chunk: int) -> List[Dict[str, int]]:
    from .sweep import run_sweep
    return run_sweep(_bucket_sweep_spec(b), max_cycles=max_cycles,
                     chunk=chunk)


def _run_bucket_composed(b: Bucket, max_cycles: Optional[int],
                         sharded_chunk: int) -> List[Dict[str, int]]:
    from .sharded import run_composed
    return run_composed(_bucket_sweep_spec(b), b.grid,
                        max_cycles=max_cycles, chunk=sharded_chunk)


def _run_bucket_sharded(b: Bucket, max_cycles: Optional[int],
                        sharded_chunk: int) -> List[Dict[str, int]]:
    import jax
    from jax.sharding import Mesh
    from .sharded import ShardedSim
    from .workloads import resolve_trace
    (sc,) = b.scenarios
    cfg = dataclasses.replace(sc.cfg, dir_layout="home")
    tr = resolve_trace(cfg, sc.app, sc.refs_per_core, sc.seed)
    rt, ct = b.tiles
    devs = np.asarray(jax.devices()[: rt * ct]).reshape(rt, ct)
    mesh = Mesh(devs, ("data", "model"))
    return [ShardedSim(cfg, tr, mesh).run(max_cycles, chunk=sharded_chunk)]


def execute_plan(plan: ExecutionPlan, max_cycles: Optional[int] = None,
                 chunk: int = 8, sharded_chunk: int = 256
                 ) -> List[Dict[str, int]]:
    """Run every bucket of ``plan`` (one compiled program each).

    Args:
        plan: the compiled plan.  A spatial/composed bucket planned for
            more devices than this process has degrades to the dense
            sweep backend instead of crashing.
        max_cycles: per-scenario cycle cap (default: each scenario's
            ``cfg.max_cycles``).
        chunk: sweep-backend cycles per in-graph termination check.
        sharded_chunk: sharded/composed-backend cycles per host-level
            dispatch (and termination/livelock check).

    Returns: one statistics dict per scenario, in the original scenario
    order — bit-identical to solo :func:`repro.core.sim.run` calls."""
    out: List[Optional[Dict[str, int]]] = [None] * len(plan.scenarios)
    for b in plan.buckets:
        if b.backend in ("sharded", "composed"):
            # the plan may have been compiled for a different ndev than
            # this process actually has; degrade to the dense backend
            # rather than crash on a short device list
            import jax
            if len(jax.devices()) < b.devices_needed:
                res = _run_bucket_sweep(b, max_cycles, chunk)
            elif b.backend == "sharded":
                res = _run_bucket_sharded(b, max_cycles, sharded_chunk)
            else:
                res = _run_bucket_composed(b, max_cycles, sharded_chunk)
        else:
            res = _run_bucket_sweep(b, max_cycles, chunk)
        for i, r in zip(b.indices, res):
            out[i] = r
    return out  # type: ignore[return-value]


def plan_and_run(scenarios: Sequence[Scenario],
                 max_cycles: Optional[int] = None, chunk: int = 8,
                 force_backend: Optional[str] = None,
                 ndev: Optional[int] = None,
                 mem_budget: Optional[int] = None) -> List[Dict[str, int]]:
    """Convenience: compile + execute in one call."""
    return execute_plan(compile_plan(scenarios, ndev, force_backend,
                                     mem_budget=mem_budget),
                        max_cycles=max_cycles, chunk=chunk)


# ---------------------------------------------------------------------------
# Manifests
# ---------------------------------------------------------------------------

_WORKLOAD_KEYS = ("app", "seed", "refs_per_core", "refs")


def _scenario_from_entry(entry: Dict, base: SimConfig) -> Scenario:
    e = dict(entry)
    app = e.pop("app", "matmul")
    seed = int(e.pop("seed", 0))
    refs_long = e.pop("refs_per_core", None)
    refs_short = e.pop("refs", None)
    if refs_long is not None and refs_short is not None:
        raise ValueError(f"scenario {entry} sets both 'refs_per_core' and "
                         "'refs'; use one")
    refs = int(refs_long if refs_long is not None
               else refs_short if refs_short is not None else 200)
    cache = e.pop("cache", None)
    if cache is not None:
        base = dataclasses.replace(base, cache=CacheConfig(**cache))
    bad = [k for k in e if k not in SimConfig.__dataclass_fields__]
    if bad:
        raise ValueError(f"unknown scenario key(s) {bad}; workload keys are "
                         f"{_WORKLOAD_KEYS}, everything else must be a "
                         f"SimConfig field")
    cfg = dataclasses.replace(base, **e) if e else base
    return Scenario(cfg=cfg, app=app, seed=seed, refs_per_core=refs)


_MESH_RE = re.compile(r"^\d+x\d+(?::|$)", re.IGNORECASE)


def _split_compact(text: str) -> List[str]:
    """Split a compact manifest into scenario items.  ``;`` always
    separates scenarios; ``,`` separates too, EXCEPT inside a source
    spec's parameter list (``hotspot:frac=0.8,hot=2``) — a comma
    fragment that does not start with ``ROWSxCOLS`` continues the
    previous item."""
    items: List[str] = []
    for semi in text.split(";"):
        open_item = False      # a ';' hard-closes the current item
        for frag in semi.split(","):
            frag = frag.strip()
            if not frag:
                continue
            if open_item and not _MESH_RE.match(frag):
                items[-1] += "," + frag
            else:
                items.append(frag)
                open_item = True
    return items


def _parse_compact(text: str, base: SimConfig) -> List[Scenario]:
    """``ROWSxCOLS[:APP][:SEED[:REFS]]`` items joined with ``;`` or ``,``.

    APP is any registry source spec and may itself contain ``:`` and
    ``,`` (``loop:matmul``, ``hotspot:frac=0.8,hot=2``): the mesh is
    parsed from the front, up to two trailing *integer* fields parse as
    SEED and REFS, and everything between is the source spec.  Spell
    source parameters ``key=val`` so they are never mistaken for
    SEED/REFS."""
    out = []
    for item in _split_compact(text):
        parts = item.split(":")
        try:
            rows, cols = (int(x) for x in parts[0].lower().split("x"))
        except ValueError:
            raise ValueError(
                f"bad compact scenario {item!r}; expected "
                "ROWSxCOLS[:APP][:SEED[:REFS]] (or a path to an existing "
                "JSON manifest)") from None
        mid = parts[1:]
        tail: List[int] = []
        while mid and len(tail) < 2 and re.fullmatch(r"-?\d+", mid[-1]):
            tail.insert(0, int(mid.pop()))
        app = ":".join(mid) if mid else "matmul"
        seed = tail[0] if tail else 0
        refs = tail[1] if len(tail) > 1 else 200
        if not valid_source(app):
            raise ValueError(f"compact scenario {item!r}: bad source "
                             f"{app!r}; {source_summary()}")
        out.append(make_scenario(base, rows, cols, app, seed, refs))
    if not out:
        raise ValueError("empty compact scenario list")
    return out


def load_manifest(src: Union[str, Dict, List],
                  base: Optional[SimConfig] = None) -> List[Scenario]:
    """Load scenarios from a manifest.

    ``src`` may be a dict (``{"base": {...}, "scenarios": [...]}``), a bare
    list of scenario dicts, a JSON string of either, a path to a JSON file,
    or the compact CLI grammar (see :func:`_parse_compact`)."""
    base = base or SimConfig()
    obj: Union[Dict, List]
    if isinstance(src, str):
        text = src.strip()
        if text.startswith("{") or text.startswith("["):
            obj = json.loads(text)
        elif os.path.exists(src):
            with open(src) as f:
                obj = json.load(f)
        elif text.endswith(".json") or os.sep in text:
            # clearly a file path, not the compact grammar: fail as one
            raise FileNotFoundError(f"manifest file not found: {src}")
        else:
            return _parse_compact(text, base)
    else:
        obj = src
    if isinstance(obj, list):
        obj = {"scenarios": obj}
    base_kw = dict(obj.get("base", {}))
    cache = base_kw.pop("cache", None)
    if cache is not None:
        base = dataclasses.replace(base, cache=CacheConfig(**cache))
    if base_kw:
        base = dataclasses.replace(base, **base_kw)
    entries = obj.get("scenarios")
    if not entries:
        raise ValueError("manifest has no scenarios")
    return [_scenario_from_entry(e, base) for e in entries]
