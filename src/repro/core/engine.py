"""Unified execution-plan layer: one engine behind run / sweep / sharded.

A *plan* turns a heterogeneous list of :class:`Scenario` — any mix of mesh
shapes, apps, seeds and policy knobs — into the minimal set of device
programs:

1. **Bucket** scenarios by structural configuration: everything that
   changes array shapes or compiled structure (mesh shape, cache geometry,
   latencies, directory layout, queue/ROB depths, cycle budget).  Policy
   knobs (migration on/off, migrate threshold, centralized vs distributed
   directory) are *traced* per-scenario state in the batched driver, so
   they never split a bucket — scenarios that differ only in workload or
   knobs share ONE compiled program.
2. **Choose a backend per bucket** with a cost model over
   ``(batch, nodes, devices)``:

   * ``sweep`` — the vmapped batched driver (:mod:`repro.core.sweep`),
     scenario axis sharded over local devices.  A batch of one is the
     classic solo run; both ride the same compiled loop.
   * ``sharded`` — the 2-D spatial ``shard_map`` decomposition
     (:mod:`repro.core.sharded`), for a single huge scenario whose node
     grid is worth splitting across devices.  The device grid is factored
     automatically (:func:`choose_tiling`); on one device, or when no
     factoring divides the mesh, the plan falls back to ``sweep`` instead
     of asserting.

3. **Execute** buckets sequentially (each is one compiled program) and
   reassemble per-scenario statistics in the original scenario order —
   bit-identical to running each scenario through a solo
   :func:`repro.core.sim.run`.

Manifests: :func:`load_manifest` accepts a JSON object/list (or a path to
one), or the compact CLI grammar ``ROWSxCOLS:APP:SEED[:REFS]`` joined with
``;`` or ``,``::

    {"base": {"addr_bits": 16, "centralized_directory": false},
     "scenarios": [
       {"rows": 8,  "cols": 8,  "app": "matmul", "seed": 0, "refs_per_core": 50},
       {"rows": 16, "cols": 16, "app": "equake", "seed": 1,
        "migration_enabled": false}]}

This layer is the architectural precondition for the ROADMAP's
scenario x row x col device-mesh composition: scenario-parallel and
space-parallel execution are now two backends behind one planner.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .config import CacheConfig, SimConfig
from .trace import TRACE_APPS

__all__ = [
    "Scenario", "Bucket", "ExecutionPlan", "make_scenario", "bucket_key",
    "choose_tiling", "backend_cost", "choose_backend", "compile_plan",
    "execute_plan", "plan_and_run", "load_manifest", "expose_host_devices",
]


def expose_host_devices() -> None:
    """Expose CPU cores as XLA host devices so the sweep backend can shard
    the scenario axis.  Must run before the first jax import; a no-op when
    the flag is already set (so explicit user pins win) or jax is loaded."""
    import sys
    if "jax" not in sys.modules \
            and "--xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={os.cpu_count()}")

#: SimConfig fields carried as traced per-scenario state by the batched
#: driver (SimState.knob_*) — these never force a new bucket/compile.
KNOB_FIELDS = ("migration_enabled", "migrate_threshold",
               "centralized_directory")
_KNOB_NORM = dict(migration_enabled=True, migrate_threshold=3,
                  centralized_directory=False)

# Cost model constants (driver work per simulated cycle, in node-units).
#: relative per-node cost of a sharded tile vs the dense single-device
#: step: halo ppermutes + the global-termination psum.
HALO_OVERHEAD = 1.25
#: fixed per-cycle cost of the sharded backend's collectives (latency-
#: bound, independent of tile size) — keeps small meshes off shard_map.
SHARD_FIXED = 4096


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One unit of work for the planner: a fully-resolved config plus a
    workload.  ``cfg`` carries everything, including policy knobs; the
    planner decides what is structural and what is traced."""

    cfg: SimConfig
    app: str = "matmul"            # TRACE_APPS name or "random"
    seed: int = 0
    refs_per_core: int = 200

    def validate(self) -> None:
        self.cfg.validate()
        if self.app != "random" and self.app not in TRACE_APPS:
            raise ValueError(f"unknown app {self.app!r}; choose from "
                             f"{sorted(TRACE_APPS)} or 'random'")
        if self.refs_per_core < 1:
            raise ValueError("refs_per_core must be >= 1")


def make_scenario(base: SimConfig, rows: Optional[int] = None,
                  cols: Optional[int] = None, app: str = "matmul",
                  seed: int = 0, refs_per_core: int = 200,
                  **overrides) -> Scenario:
    """Scenario constructor: ``base`` config + shape + any SimConfig
    overrides (structural or knob — the planner sorts out which)."""
    kw = dict(overrides)
    if rows is not None:
        kw["rows"] = rows
    if cols is not None:
        kw["cols"] = cols
    cfg = dataclasses.replace(base, **kw) if kw else base
    return Scenario(cfg=cfg, app=app, seed=seed, refs_per_core=refs_per_core)


def bucket_key(cfg: SimConfig) -> SimConfig:
    """Structural identity of a config: the config with every traced knob
    normalized away.  Two scenarios with equal keys share one compiled
    program."""
    return dataclasses.replace(cfg, **_KNOB_NORM)


def choose_tiling(rows: int, cols: int, ndev: int) -> Tuple[int, int]:
    """Factor the device count into a ``(row_tiles, col_tiles)`` grid that
    divides the simulated mesh, using as many devices as possible and
    preferring near-square tilings (halo perimeter ~ rt+ct).  Returns
    ``(1, 1)`` when nothing but a single device fits — the planner then
    falls back to the dense backend instead of asserting."""
    best = (1, 1)
    for d in range(min(ndev, rows * cols), 1, -1):
        cands = [(rt, d // rt) for rt in range(1, d + 1)
                 if d % rt == 0 and rows % rt == 0 and cols % (d // rt) == 0]
        if cands:
            return min(cands, key=lambda t: abs(t[0] - t[1]))
    return best


def backend_cost(backend: str, batch: int, nodes: int, ndev: int,
                 tiles: Tuple[int, int] = (1, 1)) -> float:
    """Estimated driver work per simulated cycle, in node-units on the
    critical path (lower is better)."""
    if backend == "sweep":
        # deferred import: sweep pulls in jax, which plan compilation with
        # an explicit ndev otherwise never needs
        from .sweep import scenario_device_count
        # run_sweep pads the batch to a multiple of the device count, so
        # wall-clock work is ceil(batch / devices) scenario-steps
        n = scenario_device_count(batch, ndev)
        return nodes * -(-batch // n)
    if backend == "sharded":
        nt = tiles[0] * tiles[1]
        if batch != 1 or nt <= 1:
            return float("inf")
        return nodes / nt * HALO_OVERHEAD + SHARD_FIXED
    raise ValueError(f"unknown backend {backend!r}")


def choose_backend(cfg: SimConfig, batch: int, ndev: int,
                   force: Optional[str] = None
                   ) -> Tuple[str, Tuple[int, int], str]:
    """Pick ``(backend, tiles, note)`` for one bucket.

    ``force`` pins the backend (CLI ``--sharded`` / ``--sweep``); a forced
    ``sharded`` that is structurally impossible (one device, centralized
    directory, batch > 1, or an indivisible mesh) degrades to ``sweep``
    with an explanatory note instead of asserting."""
    tiles = choose_tiling(cfg.rows, cfg.cols, ndev)
    eligible = (batch == 1 and not cfg.centralized_directory
                and tiles != (1, 1))
    if force == "sweep":
        return "sweep", (1, 1), "forced"
    if force == "sharded":
        if eligible:
            return "sharded", tiles, "forced"
        why = ("batch > 1" if batch > 1
               else "centralized directory" if cfg.centralized_directory
               else f"no device tiling divides {cfg.rows}x{cfg.cols} "
                    f"over {ndev} device(s)")
        return "sweep", (1, 1), f"sharded unavailable ({why}); fell back"
    if force is not None:
        raise ValueError(f"unknown backend {force!r}")
    c_sweep = backend_cost("sweep", batch, cfg.num_nodes, ndev)
    if eligible:
        c_shard = backend_cost("sharded", batch, cfg.num_nodes, ndev, tiles)
        if c_shard < c_sweep:
            return "sharded", tiles, (f"cost {c_shard:.0f} < sweep "
                                      f"{c_sweep:.0f}")
    return "sweep", (1, 1), ""


@dataclasses.dataclass(frozen=True)
class Bucket:
    """Scenarios sharing one structural config → one compiled program."""

    cfg: SimConfig                     # structural (knob-normalized) config
    scenarios: Tuple[Scenario, ...]
    indices: Tuple[int, ...]           # positions in the original list
    backend: str                       # "sweep" | "sharded"
    tiles: Tuple[int, int] = (1, 1)
    note: str = ""

    @property
    def batch(self) -> int:
        return len(self.scenarios)


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    scenarios: Tuple[Scenario, ...]
    buckets: Tuple[Bucket, ...]
    ndev: int

    def describe(self) -> Dict:
        return {
            "n_scenarios": len(self.scenarios),
            "n_buckets": len(self.buckets),
            "devices": self.ndev,
            "buckets": [{
                "rows": b.cfg.rows, "cols": b.cfg.cols, "batch": b.batch,
                "backend": b.backend,
                **({"tiles": list(b.tiles)} if b.backend == "sharded" else {}),
                **({"note": b.note} if b.note else {}),
            } for b in self.buckets],
        }


def compile_plan(scenarios: Sequence[Scenario], ndev: Optional[int] = None,
                 force_backend: Optional[str] = None) -> ExecutionPlan:
    """Bucket scenarios by structural config and choose each bucket's
    backend.  Deterministic: bucket order follows first appearance in
    ``scenarios``; per-bucket scenario order follows the input order."""
    if not scenarios:
        raise ValueError("empty plan")
    for sc in scenarios:
        sc.validate()
    if ndev is None:
        import jax
        ndev = len(jax.local_devices())

    groups: Dict[SimConfig, List[int]] = {}
    for i, sc in enumerate(scenarios):
        groups.setdefault(bucket_key(sc.cfg), []).append(i)

    buckets = []
    for key, idxs in groups.items():
        scs = tuple(scenarios[i] for i in idxs)
        # the knob check must see the *scenario* configs, not the
        # normalized key: forced-sharded eligibility depends on them
        any_central = any(sc.cfg.centralized_directory for sc in scs)
        probe = dataclasses.replace(key, centralized_directory=any_central)
        backend, tiles, note = choose_backend(probe, len(scs), ndev,
                                              force_backend)
        buckets.append(Bucket(cfg=key, scenarios=scs, indices=tuple(idxs),
                              backend=backend, tiles=tiles, note=note))
    return ExecutionPlan(tuple(scenarios), tuple(buckets), ndev)


def _run_bucket_sweep(b: Bucket, max_cycles: Optional[int],
                      chunk: int) -> List[Dict[str, int]]:
    from .sweep import ScenarioSpec, SweepSpec, run_sweep
    spec = SweepSpec(b.cfg, tuple(
        ScenarioSpec(
            app=sc.app, seed=sc.seed, refs_per_core=sc.refs_per_core,
            migration_enabled=sc.cfg.migration_enabled,
            migrate_threshold=sc.cfg.migrate_threshold,
            centralized_directory=sc.cfg.centralized_directory,
        ) for sc in b.scenarios))
    return run_sweep(spec, max_cycles=max_cycles, chunk=chunk)


def _run_bucket_sharded(b: Bucket, max_cycles: Optional[int],
                        sharded_chunk: int) -> List[Dict[str, int]]:
    import jax
    from jax.sharding import Mesh
    from .sharded import ShardedSim
    from .trace import app_trace, random_trace
    (sc,) = b.scenarios
    cfg = dataclasses.replace(sc.cfg, dir_layout="home")
    tr = (random_trace(cfg, sc.refs_per_core, sc.seed) if sc.app == "random"
          else app_trace(cfg, sc.app, sc.refs_per_core, sc.seed))
    rt, ct = b.tiles
    devs = np.asarray(jax.devices()[: rt * ct]).reshape(rt, ct)
    mesh = Mesh(devs, ("data", "model"))
    return [ShardedSim(cfg, tr, mesh).run(max_cycles, chunk=sharded_chunk)]


def execute_plan(plan: ExecutionPlan, max_cycles: Optional[int] = None,
                 chunk: int = 8, sharded_chunk: int = 256
                 ) -> List[Dict[str, int]]:
    """Run every bucket (one compiled program each) and return one stats
    dict per scenario, in the original scenario order."""
    out: List[Optional[Dict[str, int]]] = [None] * len(plan.scenarios)
    for b in plan.buckets:
        if b.backend == "sharded":
            # the plan may have been compiled for a different ndev than
            # this process actually has; degrade to the dense backend
            # rather than crash on a short device list
            import jax
            if len(jax.devices()) >= b.tiles[0] * b.tiles[1]:
                res = _run_bucket_sharded(b, max_cycles, sharded_chunk)
            else:
                res = _run_bucket_sweep(b, max_cycles, chunk)
        else:
            res = _run_bucket_sweep(b, max_cycles, chunk)
        for i, r in zip(b.indices, res):
            out[i] = r
    return out  # type: ignore[return-value]


def plan_and_run(scenarios: Sequence[Scenario],
                 max_cycles: Optional[int] = None, chunk: int = 8,
                 force_backend: Optional[str] = None,
                 ndev: Optional[int] = None) -> List[Dict[str, int]]:
    """Convenience: compile + execute in one call."""
    return execute_plan(compile_plan(scenarios, ndev, force_backend),
                        max_cycles=max_cycles, chunk=chunk)


# ---------------------------------------------------------------------------
# Manifests
# ---------------------------------------------------------------------------

_WORKLOAD_KEYS = ("app", "seed", "refs_per_core", "refs")


def _scenario_from_entry(entry: Dict, base: SimConfig) -> Scenario:
    e = dict(entry)
    app = e.pop("app", "matmul")
    seed = int(e.pop("seed", 0))
    refs_long = e.pop("refs_per_core", None)
    refs_short = e.pop("refs", None)
    if refs_long is not None and refs_short is not None:
        raise ValueError(f"scenario {entry} sets both 'refs_per_core' and "
                         "'refs'; use one")
    refs = int(refs_long if refs_long is not None
               else refs_short if refs_short is not None else 200)
    cache = e.pop("cache", None)
    if cache is not None:
        base = dataclasses.replace(base, cache=CacheConfig(**cache))
    bad = [k for k in e if k not in SimConfig.__dataclass_fields__]
    if bad:
        raise ValueError(f"unknown scenario key(s) {bad}; workload keys are "
                         f"{_WORKLOAD_KEYS}, everything else must be a "
                         f"SimConfig field")
    cfg = dataclasses.replace(base, **e) if e else base
    return Scenario(cfg=cfg, app=app, seed=seed, refs_per_core=refs)


def _parse_compact(text: str, base: SimConfig) -> List[Scenario]:
    """``ROWSxCOLS:APP:SEED[:REFS]`` items joined with ``;`` or ``,``."""
    out = []
    for item in text.replace(";", ",").split(","):
        item = item.strip()
        if not item:
            continue
        parts = item.split(":")
        try:
            rows, cols = (int(x) for x in parts[0].lower().split("x"))
        except ValueError:
            raise ValueError(
                f"bad compact scenario {item!r}; expected "
                "ROWSxCOLS:APP:SEED[:REFS] (or a path to an existing "
                "JSON manifest)") from None
        if len(parts) > 4:
            raise ValueError(f"compact scenario {item!r} has "
                             f"{len(parts) - 1} fields; only "
                             "APP:SEED:REFS follow ROWSxCOLS")
        app = parts[1] if len(parts) > 1 else "matmul"
        seed = int(parts[2]) if len(parts) > 2 else 0
        refs = int(parts[3]) if len(parts) > 3 else 200
        out.append(make_scenario(base, rows, cols, app, seed, refs))
    if not out:
        raise ValueError("empty compact scenario list")
    return out


def load_manifest(src: Union[str, Dict, List],
                  base: Optional[SimConfig] = None) -> List[Scenario]:
    """Load scenarios from a manifest.

    ``src`` may be a dict (``{"base": {...}, "scenarios": [...]}``), a bare
    list of scenario dicts, a JSON string of either, a path to a JSON file,
    or the compact CLI grammar (see :func:`_parse_compact`)."""
    base = base or SimConfig()
    obj: Union[Dict, List]
    if isinstance(src, str):
        text = src.strip()
        if text.startswith("{") or text.startswith("["):
            obj = json.loads(text)
        elif os.path.exists(src):
            with open(src) as f:
                obj = json.load(f)
        elif text.endswith(".json") or os.sep in text:
            # clearly a file path, not the compact grammar: fail as one
            raise FileNotFoundError(f"manifest file not found: {src}")
        else:
            return _parse_compact(text, base)
    else:
        obj = src
    if isinstance(obj, list):
        obj = {"scenarios": obj}
    base_kw = dict(obj.get("base", {}))
    cache = base_kw.pop("cache", None)
    if cache is not None:
        base = dataclasses.replace(base, cache=CacheConfig(**cache))
    if base_kw:
        base = dataclasses.replace(base, **base_kw)
    entries = obj.get("scenarios")
    if not entries:
        raise ValueError("manifest has no scenarios")
    return [_scenario_from_entry(e, base) for e in entries]
