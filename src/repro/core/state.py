"""Vectorized simulator state (structure-of-arrays pytree).

The GPU version's ``struct Flit / Router / Core`` (paper §6.2.1) become dense
``int32`` arrays over all N = rows*cols nodes — the TPU-native layout
(DESIGN.md §2).  All semantic rules S1..S13 are defined in
:mod:`repro.core.ref_serial`; this module only lays out state.

Flit field order (axis -1 of ``inp`` / arbitration candidates):
    0 VALID, 1 AGE, 2 SRC, 3 DST, 4 OSRC, 5 TYP, 6 TAG, 7 PKT, 8 FID, 9 NFL
Send-queue descriptor fields: 0 TYP, 1 DST, 2 OSRC, 3 TAG, 4 PKT, 5 NFL
ROB slot fields: 0 SRC, 1 PKT, 2 TYP, 3 TAG, 4 OSRC, 5 NFL, 6 CNT
Pending-completion slot fields: 0 VALID, 1 TYP, 2 SRC, 3 OSRC, 4 TAG
(the pending-completion state is a per-node FIFO of ``cfg.pc_depth`` such
slots, head at index 0 — depth 1 is the paper's single S14 register)
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

from .config import NUM_PORTS, SimConfig
from .ref_serial import STAT_NAMES

# flit fields
F_VALID, F_AGE, F_SRC, F_DST, F_OSRC, F_TYP, F_TAG, F_PKT, F_FID, F_NFL = range(10)
NUM_F = 10
# queue descriptor fields
Q_TYP, Q_DST, Q_OSRC, Q_TAG, Q_PKT, Q_NFL = range(6)
NUM_Q = 6
# rob fields
R_SRC, R_PKT, R_TYP, R_TAG, R_OSRC, R_NFL, R_CNT = range(7)
NUM_R = 7
# pending fields
P_VALID, P_TYP, P_SRC, P_OSRC, P_TAG = range(5)
NUM_P = 5

STAT_INDEX = {k: i for i, k in enumerate(STAT_NAMES)}
NUM_STATS = len(STAT_NAMES)


class SimState(NamedTuple):
    # FSM (N,)
    st: jnp.ndarray
    ctr: jnp.ndarray
    tr_ptr: jnp.ndarray
    pend_addr: jnp.ndarray
    install_mode: jnp.ndarray
    pkt_ctr: jnp.ndarray
    lru_clock: jnp.ndarray
    # caches
    l1_tag: jnp.ndarray      # (N, S1, W1)
    l1_lru: jnp.ndarray
    l1_owner: jnp.ndarray
    l2_tag: jnp.ndarray      # (N, S2, W2)
    l2_lru: jnp.ndarray
    l2_mig: jnp.ndarray
    l2_last: jnp.ndarray
    l2_streak: jnp.ndarray
    # directory: (dir_entries + 1,) — last slot is a scatter sink
    dir_loc: jnp.ndarray
    # forwarding table
    fwd_tag: jnp.ndarray     # (N, Fe)
    fwd_dst: jnp.ndarray
    fwd_ptr: jnp.ndarray     # (N,)
    # network input ports
    inp: jnp.ndarray         # (N, 4, NUM_F)
    # send queue (packet ring buffer)
    q_desc: jnp.ndarray      # (N, Qp, NUM_Q)
    q_head: jnp.ndarray      # (N,)
    q_size: jnp.ndarray      # (N,)
    q_fid: jnp.ndarray       # (N,)  flit cursor of head packet
    # reorder buffer
    rob: jnp.ndarray         # (N, K, NUM_R)
    # pending-completion queue (head at slot 0; depth 1 = S14 register)
    pc: jnp.ndarray          # (N, pc_depth, NUM_P)
    # statistics + clock
    stats: jnp.ndarray       # (NUM_STATS,) int32
    cycle: jnp.ndarray       # () int32
    # workload (read-only during sim)
    trace: jnp.ndarray       # (N, M)
    # policy knobs as traced scalars so a batched sweep (repro.core.sweep)
    # can vary them per scenario inside ONE compiled program; initialized
    # from SimConfig so solo runs are unchanged.
    knob_mig: jnp.ndarray      # () int32 — migration enabled?
    knob_mig_thr: jnp.ndarray  # () int32 — migration streak threshold
    knob_central: jnp.ndarray  # () int32 — centralized directory?
    knob_ej_age: jnp.ndarray   # () int32 — guaranteed-ejection age threshold


class Geometry(NamedTuple):
    """Static (numpy) routing geometry, precomputed from the config."""

    valid_port: np.ndarray   # (N, 4) bool — does this port physically exist
    gather_node: np.ndarray  # (N, 4) int32 — node whose output feeds my input p
    gather_port: np.ndarray  # (4,) int32 — which output port of that node
    node_r: np.ndarray       # (N,)
    node_c: np.ndarray       # (N,)


class NodeCtx(NamedTuple):
    """Per-node identity/geometry as *arrays* (shardable: inside shard_map
    these are the local tile's slices; node ids stay global)."""

    node_id: jnp.ndarray     # (Nl,) global node id (r*C + c)
    node_r: jnp.ndarray      # (Nl,) global row
    node_c: jnp.ndarray      # (Nl,) global col
    valid_port: jnp.ndarray  # (Nl, 4) bool


def make_node_ctx(cfg: SimConfig) -> NodeCtx:
    geo = make_geometry(cfg.rows, cfg.cols)
    return NodeCtx(jnp.arange(cfg.num_nodes, dtype=jnp.int32),
                   jnp.asarray(geo.node_r), jnp.asarray(geo.node_c),
                   jnp.asarray(geo.valid_port))


def make_geometry(rows: int, cols: int) -> Geometry:
    n = rows * cols
    idx = np.arange(n)
    r, c = idx // cols, idx % cols
    valid = np.stack([r > 0, c < cols - 1, r < rows - 1, c > 0], axis=1)  # N,E,S,W
    # input port p receives the opposite output of the neighbour in direction p
    gnode = np.stack([idx - cols, idx + 1, idx + cols, idx - 1], axis=1)
    gnode = np.where(valid, gnode, 0).astype(np.int32)
    gport = np.array([2, 3, 0, 1], np.int32)  # S, W, N, E
    return Geometry(valid.astype(bool), gnode, gport,
                    r.astype(np.int32), c.astype(np.int32))


def dir_shape(cfg: SimConfig) -> Tuple[int, ...]:
    """Directory array shape. ``flat``: one global location array (+ sink
    slot).  ``home``: entry for tag t lives at (t % N, t // N) — row-sharded
    with the nodes, so every access is local to the tag's home node."""
    if cfg.dir_layout == "flat":
        return (cfg.dir_entries + 1,)
    assert not cfg.centralized_directory, \
        "home-sharded directory layout requires a distributed directory"
    per = -(-cfg.dir_entries // cfg.num_nodes)
    return (cfg.num_nodes, per + 1)


def init_state(cfg: SimConfig, trace: np.ndarray) -> SimState:
    """Build the initial state.

    ``trace`` is ``(num_nodes, M)`` for a solo run, or ``(B, num_nodes, M)``
    for a batched sweep — every leaf then carries the same leading scenario
    axis ``B`` (see :mod:`repro.core.sweep`).
    """
    cfg.validate()
    trace = np.asarray(trace)
    if trace.ndim not in (2, 3) or trace.shape[-2] != cfg.num_nodes:
        raise ValueError(
            f"trace must be (num_nodes, M) or (B, num_nodes, M) with "
            f"num_nodes={cfg.num_nodes}, got shape {trace.shape}")
    batch = trace.shape[:-2]
    n = cfg.num_nodes
    ca = cfg.cache
    i32 = jnp.int32
    z = lambda *s: jnp.zeros(batch + s, i32)
    neg = lambda *s: jnp.full(batch + s, -1, i32)
    knob = lambda v: jnp.full(batch, v, i32)
    return SimState(
        st=z(n), ctr=z(n), tr_ptr=z(n), pend_addr=neg(n), install_mode=z(n),
        pkt_ctr=z(n), lru_clock=z(n),
        l1_tag=neg(n, ca.l1_sets, ca.l1_ways),
        l1_lru=z(n, ca.l1_sets, ca.l1_ways),
        l1_owner=neg(n, ca.l1_sets, ca.l1_ways),
        l2_tag=neg(n, ca.l2_sets, ca.l2_ways),
        l2_lru=z(n, ca.l2_sets, ca.l2_ways),
        l2_mig=z(n, ca.l2_sets, ca.l2_ways),
        l2_last=neg(n, ca.l2_sets, ca.l2_ways),
        l2_streak=z(n, ca.l2_sets, ca.l2_ways),
        dir_loc=jnp.full(batch + dir_shape(cfg), -1, i32),
        fwd_tag=neg(n, cfg.fwd_entries), fwd_dst=neg(n, cfg.fwd_entries),
        fwd_ptr=z(n),
        inp=z(n, NUM_PORTS, NUM_F),
        q_desc=z(n, cfg.send_queue + 1, NUM_Q),   # +1 = commit sink slot
        q_head=z(n), q_size=z(n), q_fid=z(n),
        rob=z(n, cfg.rob_slots, NUM_R),
        pc=z(n, cfg.pc_depth, NUM_P),
        stats=z(NUM_STATS),
        cycle=z(),
        trace=jnp.asarray(trace, i32),
        knob_mig=knob(int(cfg.migration_enabled)),
        knob_mig_thr=knob(cfg.migrate_threshold),
        knob_central=knob(int(cfg.centralized_directory)),
        knob_ej_age=knob(cfg.eject_age_threshold),
    )


def bump(stats: jnp.ndarray, name: str, amount) -> jnp.ndarray:
    """Add ``amount`` (scalar or array to be summed) to a named statistic."""
    amt = jnp.sum(amount.astype(jnp.int32)) if hasattr(amount, "astype") else amount
    return stats.at[STAT_INDEX[name]].add(jnp.asarray(amt, jnp.int32))
