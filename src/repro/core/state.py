"""Vectorized simulator state (structure-of-arrays pytree).

The GPU version's ``struct Flit / Router / Core`` (paper §6.2.1) become dense
arrays over all N = rows*cols nodes — the TPU-native layout
(DESIGN.md §2).  All semantic rules S1..S13 are defined in
:mod:`repro.core.ref_serial`; this module only lays out state.

Storage layout is configurable (``SimConfig.state_dtype_policy``):
``"wide"`` keeps every leaf int32; ``"packed"`` gives each leaf the
smallest of int8/int16/int32 that holds its validated value bounds
(:func:`leaf_dtypes`).  All phase code computes in int32 either way —
:func:`widen_state` / :func:`narrow_state` cast at the cycle boundary
(docs/architecture.md "State layout and memory budget").

Statistics are carried as a base-2**30 (hi, lo) int32 pair (``stats_hi``,
``stats``) because jax has no int64 without the global x64 switch: the
low word is folded into the high word once per cycle
(:func:`fold_stats`), so the low word always equals ``total mod 2**30``
and counters cannot wrap at 43k nodes x long runs.  Hosts reconstruct
exact int64 totals with :func:`stats_totals`.

Flit field order (axis -1 of ``inp`` / arbitration candidates):
    0 VALID, 1 AGE, 2 SRC, 3 DST, 4 OSRC, 5 TYP, 6 TAG, 7 PKT, 8 FID, 9 NFL
Send-queue descriptor fields: 0 TYP, 1 DST, 2 OSRC, 3 TAG, 4 PKT, 5 NFL
ROB slot fields: 0 SRC, 1 PKT, 2 TYP, 3 TAG, 4 OSRC, 5 NFL, 6 CNT
Pending-completion slot fields: 0 VALID, 1 TYP, 2 SRC, 3 OSRC, 4 TAG
(the pending-completion state is a per-node FIFO of ``cfg.pc_depth`` such
slots, head at index 0 — depth 1 is the paper's single S14 register)
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .config import NUM_MSG_TYPES, NUM_PORTS, SimConfig
from .ref_serial import STAT_NAMES

# flit fields
F_VALID, F_AGE, F_SRC, F_DST, F_OSRC, F_TYP, F_TAG, F_PKT, F_FID, F_NFL = range(10)
NUM_F = 10
# queue descriptor fields
Q_TYP, Q_DST, Q_OSRC, Q_TAG, Q_PKT, Q_NFL = range(6)
NUM_Q = 6
# rob fields
R_SRC, R_PKT, R_TYP, R_TAG, R_OSRC, R_NFL, R_CNT = range(7)
NUM_R = 7
# pending fields
P_VALID, P_TYP, P_SRC, P_OSRC, P_TAG = range(5)
NUM_P = 5

STAT_INDEX = {k: i for i, k in enumerate(STAT_NAMES)}
NUM_STATS = len(STAT_NAMES)


class SimState(NamedTuple):
    # FSM (N,)
    st: jnp.ndarray
    ctr: jnp.ndarray
    tr_ptr: jnp.ndarray
    pend_addr: jnp.ndarray
    install_mode: jnp.ndarray
    pkt_ctr: jnp.ndarray
    lru_clock: jnp.ndarray
    # caches
    l1_tag: jnp.ndarray      # (N, S1, W1)
    l1_lru: jnp.ndarray
    l1_owner: jnp.ndarray
    l2_tag: jnp.ndarray      # (N, S2, W2)
    l2_lru: jnp.ndarray
    l2_mig: jnp.ndarray
    l2_last: jnp.ndarray
    l2_streak: jnp.ndarray
    # directory: (dir_entries + 1,) — last slot is a scatter sink
    dir_loc: jnp.ndarray
    # forwarding table
    fwd_tag: jnp.ndarray     # (N, Fe)
    fwd_dst: jnp.ndarray
    fwd_ptr: jnp.ndarray     # (N,)
    # network input ports
    inp: jnp.ndarray         # (N, 4, NUM_F)
    # send queue (packet ring buffer)
    q_desc: jnp.ndarray      # (N, Qp, NUM_Q)
    q_head: jnp.ndarray      # (N,)
    q_size: jnp.ndarray      # (N,)
    q_fid: jnp.ndarray       # (N,)  flit cursor of head packet
    # reorder buffer
    rob: jnp.ndarray         # (N, K, NUM_R)
    # pending-completion queue (head at slot 0; depth 1 = S14 register)
    pc: jnp.ndarray          # (N, pc_depth, NUM_P)
    # statistics + clock.  stats is the LOW word of a base-2**30 pair
    # (stats_hi carries the overflow folded out once per cycle); exact
    # int64 totals come from stats_totals(stats_hi, stats).
    stats: jnp.ndarray       # (NUM_STATS,) int32 — low word (total mod 2**30)
    stats_hi: jnp.ndarray    # (NUM_STATS,) int32 — high word (total div 2**30)
    cycle: jnp.ndarray       # () int32
    # workload (read-only during sim)
    trace: jnp.ndarray       # (N, M)
    # policy knobs as traced scalars so a batched sweep (repro.core.sweep)
    # can vary them per scenario inside ONE compiled program; initialized
    # from SimConfig so solo runs are unchanged.
    knob_mig: jnp.ndarray      # () int32 — migration enabled?
    knob_mig_thr: jnp.ndarray  # () int32 — migration streak threshold
    knob_central: jnp.ndarray  # () int32 — centralized directory?
    knob_ej_age: jnp.ndarray   # () int32 — guaranteed-ejection age threshold


class Geometry(NamedTuple):
    """Static (numpy) routing geometry, precomputed from the config."""

    valid_port: np.ndarray   # (N, 4) bool — does this port physically exist
    gather_node: np.ndarray  # (N, 4) int32 — node whose output feeds my input p
    gather_port: np.ndarray  # (4,) int32 — which output port of that node
    node_r: np.ndarray       # (N,)
    node_c: np.ndarray       # (N,)


class NodeCtx(NamedTuple):
    """Per-node identity/geometry as *arrays* (shardable: inside shard_map
    these are the local tile's slices; node ids stay global)."""

    node_id: jnp.ndarray     # (Nl,) global node id (r*C + c)
    node_r: jnp.ndarray      # (Nl,) global row
    node_c: jnp.ndarray      # (Nl,) global col
    valid_port: jnp.ndarray  # (Nl, 4) bool


def make_node_ctx(cfg: SimConfig) -> NodeCtx:
    geo = make_geometry(cfg.rows, cfg.cols)
    return NodeCtx(jnp.arange(cfg.num_nodes, dtype=jnp.int32),
                   jnp.asarray(geo.node_r), jnp.asarray(geo.node_c),
                   jnp.asarray(geo.valid_port))


def make_geometry(rows: int, cols: int) -> Geometry:
    n = rows * cols
    idx = np.arange(n)
    r, c = idx // cols, idx % cols
    valid = np.stack([r > 0, c < cols - 1, r < rows - 1, c > 0], axis=1)  # N,E,S,W
    # input port p receives the opposite output of the neighbour in direction p
    gnode = np.stack([idx - cols, idx + 1, idx + cols, idx - 1], axis=1)
    gnode = np.where(valid, gnode, 0).astype(np.int32)
    gport = np.array([2, 3, 0, 1], np.int32)  # S, W, N, E
    return Geometry(valid.astype(bool), gnode, gport,
                    r.astype(np.int32), c.astype(np.int32))


def dir_shape(cfg: SimConfig) -> Tuple[int, ...]:
    """Directory array shape. ``flat``: one global location array (+ sink
    slot).  ``home``: entry for tag t lives at (t % N, t // N) — row-sharded
    with the nodes, so every access is local to the tag's home node."""
    if cfg.dir_layout == "flat":
        return (cfg.dir_entries + 1,)
    assert not cfg.centralized_directory, \
        "home-sharded directory layout requires a distributed directory"
    per = -(-cfg.dir_entries // cfg.num_nodes)
    return (cfg.num_nodes, per + 1)


def init_state(cfg: SimConfig, trace: np.ndarray) -> SimState:
    """Build the initial state.

    ``trace`` is ``(num_nodes, M)`` for a solo run, or ``(B, num_nodes, M)``
    for a batched sweep — every leaf then carries the same leading scenario
    axis ``B`` (see :mod:`repro.core.sweep`).
    """
    cfg.validate()
    if not hasattr(trace, "ndim"):   # keep tracers (eval_shape) intact
        trace = np.asarray(trace)
    if trace.ndim not in (2, 3) or trace.shape[-2] != cfg.num_nodes:
        raise ValueError(
            f"trace must be (num_nodes, M) or (B, num_nodes, M) with "
            f"num_nodes={cfg.num_nodes}, got shape {trace.shape}")
    batch = trace.shape[:-2]
    n = cfg.num_nodes
    ca = cfg.cache
    i32 = jnp.int32
    dt = leaf_dtypes(cfg, trace.shape[-1])
    z = lambda k, *s: jnp.zeros(batch + s, dt[k])
    neg = lambda k, *s: jnp.full(batch + s, -1, dt[k])
    knob = lambda v: jnp.full(batch, v, i32)
    return SimState(
        st=z("st", n), ctr=z("ctr", n), tr_ptr=z("tr_ptr", n),
        pend_addr=neg("pend_addr", n), install_mode=z("install_mode", n),
        pkt_ctr=z("pkt_ctr", n), lru_clock=z("lru_clock", n),
        l1_tag=neg("l1_tag", n, ca.l1_sets, ca.l1_ways),
        l1_lru=z("l1_lru", n, ca.l1_sets, ca.l1_ways),
        l1_owner=neg("l1_owner", n, ca.l1_sets, ca.l1_ways),
        l2_tag=neg("l2_tag", n, ca.l2_sets, ca.l2_ways),
        l2_lru=z("l2_lru", n, ca.l2_sets, ca.l2_ways),
        l2_mig=z("l2_mig", n, ca.l2_sets, ca.l2_ways),
        l2_last=neg("l2_last", n, ca.l2_sets, ca.l2_ways),
        l2_streak=z("l2_streak", n, ca.l2_sets, ca.l2_ways),
        dir_loc=jnp.full(batch + dir_shape(cfg), -1, dt["dir_loc"]),
        fwd_tag=neg("fwd_tag", n, cfg.fwd_entries),
        fwd_dst=neg("fwd_dst", n, cfg.fwd_entries),
        fwd_ptr=z("fwd_ptr", n),
        inp=z("inp", n, NUM_PORTS, NUM_F),
        q_desc=z("q_desc", n, cfg.send_queue + 1, NUM_Q),  # +1 = sink slot
        q_head=z("q_head", n), q_size=z("q_size", n), q_fid=z("q_fid", n),
        rob=z("rob", n, cfg.rob_slots, NUM_R),
        pc=z("pc", n, cfg.pc_depth, NUM_P),
        stats=z("stats", NUM_STATS),
        stats_hi=z("stats_hi", NUM_STATS),
        cycle=z("cycle"),
        trace=jnp.asarray(trace, dt["trace"]),
        knob_mig=knob(int(cfg.migration_enabled)),
        knob_mig_thr=knob(cfg.migrate_threshold),
        knob_central=knob(int(cfg.centralized_directory)),
        knob_ej_age=knob(cfg.eject_age_threshold),
    )


# ---------------------------------------------------------------------------
# Narrow-dtype storage layout (SimConfig.state_dtype_policy)
# ---------------------------------------------------------------------------

#: leaves that stay int32 under every policy: the stats hi/lo pair (the
#: accumulator arithmetic needs int32 headroom), the clock, and the traced
#: knob scalars (the sweep layer swaps int32 vectors into them).
_PINNED_I32 = ("stats", "stats_hi", "cycle",
               "knob_mig", "knob_mig_thr", "knob_central", "knob_ej_age")


def _fit(lo: int, hi: int) -> np.dtype:
    """Smallest signed integer dtype holding the closed range [lo, hi]."""
    for dt in (np.int8, np.int16, np.int32):
        info = np.iinfo(dt)
        if lo >= info.min and hi <= info.max:
            return np.dtype(dt)
    raise ValueError(f"state value bounds [{lo}, {hi}] exceed int32")


@functools.lru_cache(maxsize=None)
def leaf_dtypes(cfg: SimConfig, trace_len: int) -> Dict[str, np.dtype]:
    """Per-leaf storage dtype map for ``cfg`` (keyed by SimState field).

    ``wide`` pins every leaf to int32 (the historical layout).  ``packed``
    derives each leaf's value bounds from the validated config — FSM
    states 0..6, tags ``<= (2**addr_bits - 1) >> shift``, node ids
    ``< num_nodes``, LRU clocks ``<= 3 * max_cycles + 4`` (at most three
    touch sites tick the clock per cycle), flit ages ``<= max_cycles``,
    packet ids ``< cfg.pkt_wrap`` — and picks the smallest of
    int8/int16/int32 that holds them (``-1`` sentinels included).  The
    map therefore *adapts*: node-id leaves widen back to int32 past
    32767 nodes, message payloads past ``addr_bits`` 15, LRU clocks past
    ``max_cycles`` ~10900.  Bounds the config cannot express (e.g. a
    migration streak past int16 saturation) are rejected by
    ``SimConfig.validate`` instead.
    """
    i32 = np.dtype(np.int32)
    out = {k: i32 for k in SimState._fields}
    if cfg.state_dtype_policy != "packed":
        return out
    n = cfg.num_nodes
    addr_max = (1 << cfg.addr_bits) - 1
    clk_max = 3 * cfg.max_cycles + 4
    ctr_max = max(cfg.mem_cycles, cfg.l2_hit_cycles, cfg.l1_miss_cycles,
                  cfg.req_timeout) + 1
    flits_max = 16          # longest packet (B2) — FLITS_OF
    # every value a flit/descriptor/ROB/pending slot can carry: a message
    # type, a node id, a tag or address payload, a packet id, an age, a
    # flit count, or a -1 sentinel
    msg_hi = max(addr_max, n - 1, cfg.pkt_wrap - 1, cfg.max_cycles,
                 flits_max, NUM_MSG_TYPES)
    out.update(
        st=_fit(0, 6),
        ctr=_fit(-2, ctr_max),
        tr_ptr=_fit(0, trace_len + 1),
        pend_addr=_fit(-1, addr_max),
        install_mode=_fit(0, 1),
        # pkt_ctr may wrap in a narrow dtype: safe, because consumers only
        # ever read it mod cfg.pkt_wrap (2**14), and 2**16 = 0 mod 2**14
        pkt_ctr=_fit(0, cfg.pkt_wrap - 1),
        lru_clock=_fit(0, clk_max),
        l1_tag=_fit(-1, addr_max >> cfg.cache.l1_shift),
        l1_lru=_fit(0, clk_max),
        l1_owner=_fit(-1, n - 1),
        l2_tag=_fit(-1, addr_max >> cfg.cache.l2_shift),
        l2_lru=_fit(0, clk_max),
        l2_mig=_fit(0, 1),
        l2_last=_fit(-1, n - 1),
        l2_streak=np.dtype(np.int16),   # saturating narrow (see below)
        dir_loc=_fit(-1, n - 1),
        fwd_tag=_fit(-1, addr_max >> cfg.cache.l2_shift),
        fwd_dst=_fit(-1, n - 1),
        fwd_ptr=_fit(0, cfg.fwd_entries),
        inp=_fit(-1, msg_hi),
        q_desc=_fit(-1, msg_hi),
        q_head=_fit(0, cfg.send_queue),
        q_size=_fit(0, cfg.send_queue + 1),
        q_fid=_fit(0, flits_max),
        rob=_fit(-1, msg_hi),
        pc=_fit(-1, msg_hi),
        trace=_fit(-1, addr_max),
    )
    for k in _PINNED_I32:
        out[k] = i32
    return out


def widen_state(s: SimState) -> SimState:
    """Cast every narrow leaf up to the int32 compute domain.

    ``trace`` is exempt: it is read-only during simulation and its single
    consumer (``cache._next_addr``) casts after the gather, so the full
    (N, M) block is never re-materialized per cycle.  Under the wide
    policy every cast is a no-op and XLA elides it.
    """
    i32 = jnp.int32
    return SimState(**{
        k: (v if k == "trace" or v.dtype == i32 else v.astype(i32))
        for k, v in s._asdict().items()})


def narrow_state(s: SimState, dtypes: Dict[str, np.dtype]) -> SimState:
    """Cast leaves back down to their storage dtypes (``leaf_dtypes``).

    All casts are value-preserving by the bounds in :func:`leaf_dtypes`,
    with two deliberate exceptions: ``pkt_ctr`` may wrap (congruent mod
    ``cfg.pkt_wrap``, so packet ids are unchanged) and ``l2_streak``
    saturates at int16 max (comparisons against the validated
    ``migrate_threshold <= 32766`` are unaffected).
    """
    def down(k, v):
        dt = dtypes[k]
        if v.dtype == dt:
            return v
        if k == "l2_streak":
            v = jnp.minimum(v, np.iinfo(np.int16).max)
        return v.astype(dt)
    return SimState(**{k: down(k, v) for k, v in s._asdict().items()})


def state_bytes(cfg: SimConfig, trace_len: int = 200,
                policy: Optional[str] = None) -> int:
    """Exact SimState bytes for ONE scenario of ``cfg`` (trace included).

    ``policy`` overrides ``cfg.state_dtype_policy`` (so planners can
    quote both layouts without rebuilding configs).  Pure shape/dtype
    arithmetic — no device allocation.
    """
    if policy is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, state_dtype_policy=policy)
    cfg.validate()
    n, ca = cfg.num_nodes, cfg.cache
    shapes = dict(
        st=(n,), ctr=(n,), tr_ptr=(n,), pend_addr=(n,), install_mode=(n,),
        pkt_ctr=(n,), lru_clock=(n,),
        l1_tag=(n, ca.l1_sets, ca.l1_ways), l1_lru=(n, ca.l1_sets, ca.l1_ways),
        l1_owner=(n, ca.l1_sets, ca.l1_ways),
        l2_tag=(n, ca.l2_sets, ca.l2_ways), l2_lru=(n, ca.l2_sets, ca.l2_ways),
        l2_mig=(n, ca.l2_sets, ca.l2_ways), l2_last=(n, ca.l2_sets, ca.l2_ways),
        l2_streak=(n, ca.l2_sets, ca.l2_ways),
        dir_loc=dir_shape(cfg),
        fwd_tag=(n, cfg.fwd_entries), fwd_dst=(n, cfg.fwd_entries),
        fwd_ptr=(n,),
        inp=(n, NUM_PORTS, NUM_F),
        q_desc=(n, cfg.send_queue + 1, NUM_Q),
        q_head=(n,), q_size=(n,), q_fid=(n,),
        rob=(n, cfg.rob_slots, NUM_R), pc=(n, cfg.pc_depth, NUM_P),
        stats=(NUM_STATS,), stats_hi=(NUM_STATS,), cycle=(),
        trace=(n, trace_len),
        knob_mig=(), knob_mig_thr=(), knob_central=(), knob_ej_age=(),
    )
    dt = leaf_dtypes(cfg, trace_len)
    return sum(int(np.prod(shp, dtype=np.int64)) * dt[k].itemsize
               for k, shp in shapes.items())


# ---------------------------------------------------------------------------
# 64-bit statistics accumulator (base-2**30 hi/lo int32 pair)
# ---------------------------------------------------------------------------

#: fold base.  Per-cycle increments stay far below 2**31 - 2**30, so the
#: low word never overflows between folds even at 43k nodes.
STATS_FOLD = 1 << 30


def fold_stats(hi: jnp.ndarray, lo: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Carry the low stats word into the high word: returns the canonical
    pair with ``lo = total mod 2**30`` (floor semantics, so a negative
    transient ``lo`` — possible after summing sharded per-tile deltas —
    normalizes correctly)."""
    carry = jnp.floor_divide(lo, STATS_FOLD)
    return hi + carry, lo - carry * STATS_FOLD


def stats_totals(hi, lo) -> np.ndarray:
    """Exact int64 counter totals from a (hi, lo) stats pair (host side)."""
    return (np.asarray(hi, np.int64) * STATS_FOLD
            + np.asarray(lo, np.int64))


def bump(stats: jnp.ndarray, name: str, amount) -> jnp.ndarray:
    """Add ``amount`` (scalar or array to be summed) to a named statistic.

    ``stats`` is the LOW word of the base-2**30 accumulator pair; the
    per-cycle fold in ``sim.cycle_step`` carries overflow into
    ``stats_hi``, so totals are exact int64 end to end (host view:
    :func:`stats_totals`)."""
    amt = jnp.sum(amount.astype(jnp.int32)) if hasattr(amount, "astype") else amount
    return stats.at[STAT_INDEX[name]].add(jnp.asarray(amt, jnp.int32))
