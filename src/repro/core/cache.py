"""Vectorized phase 1: LSPD cache / directory / migration FSM.

Implements rules S1..S14 of :mod:`repro.core.ref_serial` as masked dense
array ops over all (local) nodes at once.  Every function takes a
:class:`repro.core.state.NodeCtx` carrying *global* node identity as arrays,
so the same code runs on the whole mesh (single device) or on a tile of it
(inside ``shard_map``).  Directory accesses are always performed by the
tag's home node, which makes the ``home`` directory layout fully local.
"""
from __future__ import annotations

from typing import List, NamedTuple, Tuple

import jax.numpy as jnp

from .config import (
    FLITS_OF,
    INSTALL_L1_ONLY,
    INSTALL_L2,
    MSG_B2,
    MSG_DA,
    MSG_DR,
    MSG_DU,
    MSG_MIG_ACK,
    MSG_NACK,
    MSG_RA,
    MSG_REQ,
    MSG_REQ_FWD,
    MSG_WB,
    ST_DONE,
    ST_IDLE,
    ST_L1_WAIT,
    ST_L2_WAIT,
    ST_WAIT_DATA,
    ST_WAIT_DIR,
    ST_WAIT_MEM,
    SimConfig,
)
from .state import (
    NodeCtx,
    P_OSRC,
    P_SRC,
    P_TAG,
    P_TYP,
    P_VALID,
    SimState,
    bump,
)

I32 = jnp.int32
FLITS_TABLE = jnp.asarray(FLITS_OF, I32)
BIG = jnp.asarray(1 << 30, I32)


class Desc(NamedTuple):
    """A packet descriptor slot: one potential enqueue per node."""

    valid: jnp.ndarray  # (Nl,) bool
    typ: jnp.ndarray
    dst: jnp.ndarray
    osrc: jnp.ndarray
    tag: jnp.ndarray


def empty_desc(n: int) -> Desc:
    z = jnp.zeros(n, I32)
    return Desc(jnp.zeros(n, bool), z, z, z, z)


def merge_desc(a: Desc, b: Desc) -> Desc:
    """Merge two descriptor sets with disjoint valid masks."""
    pick = b.valid
    return Desc(a.valid | b.valid,
                jnp.where(pick, b.typ, a.typ),
                jnp.where(pick, b.dst, a.dst),
                jnp.where(pick, b.osrc, a.osrc),
                jnp.where(pick, b.tag, a.tag))


def dir_home_v(cfg: SimConfig, tag: jnp.ndarray,
               central=None) -> jnp.ndarray:
    """Home node of a directory entry.  ``central`` is the traced
    per-scenario knob (``SimState.knob_central``); ``None`` falls back to
    the static config (solo-run callers outside the stepped phases)."""
    home = jnp.where(tag >= 0, tag % cfg.num_nodes, 0)
    if central is None:
        if cfg.centralized_directory:
            return jnp.zeros_like(tag)
        return home
    return jnp.where(central > 0, jnp.zeros_like(tag), home)


def dir_read(dir_loc: jnp.ndarray, cfg: SimConfig, tag: jnp.ndarray,
             mask) -> jnp.ndarray:
    """Directory lookup — only ever executed by the tag's home node."""
    if cfg.dir_layout == "flat":
        idx = jnp.where(mask & (tag >= 0), tag, dir_loc.shape[0] - 1)
        return dir_loc[idx]
    row = jnp.arange(tag.shape[0], dtype=I32)
    col = jnp.where(mask & (tag >= 0), tag // cfg.num_nodes,
                    dir_loc.shape[1] - 1)
    return dir_loc[row, col]


def dir_write(dir_loc: jnp.ndarray, cfg: SimConfig, tag: jnp.ndarray,
              val: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    # masked-off rows are routed to the sink slot and write its current
    # value back, so the sink stays at its initial -1 without a separate
    # full-array reset (dir_read discards sink values via the same mask)
    eff = mask & (tag >= 0)
    if cfg.dir_layout == "flat":
        sink = dir_loc.shape[0] - 1
        idx = jnp.where(eff, tag, sink)
        return dir_loc.at[idx].set(jnp.where(eff, val, dir_loc[idx]))
    row = jnp.arange(tag.shape[0], dtype=I32)
    sink = dir_loc.shape[1] - 1
    col = jnp.where(eff, tag // cfg.num_nodes, sink)
    return dir_loc.at[row, col].set(jnp.where(eff, val, dir_loc[row, col]))


# --------------------------------------------------------------------------
# cache probes
# --------------------------------------------------------------------------

def l2_probe(s: SimState, cfg: SimConfig, tag2: jnp.ndarray):
    """Returns (set_idx, hit_way, hit) for an L2 associative probe."""
    ca = cfg.cache
    node = jnp.arange(tag2.shape[0], dtype=I32)
    si = jnp.where(tag2 >= 0, tag2 % ca.l2_sets, 0)
    tags = s.l2_tag[node, si]                     # (Nl, W2)
    hm = (tags == tag2[:, None]) & (tag2[:, None] >= 0)
    return si, jnp.argmax(hm, axis=1).astype(I32), jnp.any(hm, axis=1)


def l1_probe(s: SimState, cfg: SimConfig, addr: jnp.ndarray):
    ca = cfg.cache
    node = jnp.arange(addr.shape[0], dtype=I32)
    tag1 = jnp.where(addr >= 0, addr >> ca.l1_shift, -1)
    si = jnp.where(tag1 >= 0, tag1 % ca.l1_sets, 0)
    tags = s.l1_tag[node, si]
    hm = (tags == tag1[:, None]) & (tag1[:, None] >= 0)
    return tag1, si, jnp.argmax(hm, axis=1).astype(I32), jnp.any(hm, axis=1)


# --------------------------------------------------------------------------
# installs (S3, S5)
# --------------------------------------------------------------------------

class L2Install(NamedTuple):
    l2_tag: jnp.ndarray
    l2_mig: jnp.ndarray
    l2_last: jnp.ndarray
    l2_streak: jnp.ndarray
    ok: jnp.ndarray            # install succeeded (or already present)
    did: jnp.ndarray           # wrote a new block (touch needed)
    touch_set: jnp.ndarray
    touch_way: jnp.ndarray
    desc_duv: Desc             # remote victim dir delete
    desc_dun: Desc             # remote new-owner dir update
    dirw_vic: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]   # tag, val, mask
    dirw_new: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]
    n_local_updates: jnp.ndarray
    n_drops: jnp.ndarray


def install_l2(s: SimState, cfg: SimConfig, ctx: NodeCtx, mask: jnp.ndarray,
               tag2: jnp.ndarray) -> L2Install:
    """S5 — masked L2 install with victim eviction + directory maintenance."""
    ca = cfg.cache
    n = ctx.node_id.shape[0]
    node = jnp.arange(n, dtype=I32)
    nid = ctx.node_id
    si, hw, present_any = l2_probe(s, cfg, jnp.where(mask, tag2, -1))
    present = mask & present_any
    need = mask & ~present

    tags = s.l2_tag[node, si]                        # (Nl, W2)
    migf = s.l2_mig[node, si]
    lru = s.l2_lru[node, si]
    inv = tags < 0
    has_inv = jnp.any(inv, axis=1)
    inv_way = jnp.argmax(inv, axis=1).astype(I32)
    lru_key = lru + migf * BIG
    lru_way = jnp.argmin(lru_key, axis=1).astype(I32)
    all_mig = jnp.all(migf > 0, axis=1)
    vic_way = jnp.where(has_inv, inv_way, lru_way)
    fail = need & ~has_inv & all_mig
    do = need & ~fail
    vic_valid = do & ~has_inv
    vtag = tags[node, vic_way]

    # victim directory delete (S4)
    homev = dir_home_v(cfg, vtag, s.knob_central)
    vlocal = vic_valid & (homev == nid)
    vremote = vic_valid & ~vlocal
    cur_v = dir_read(s.dir_loc, cfg, vtag, vlocal)
    vval = jnp.where(cur_v == nid, -1, cur_v)
    desc_duv = Desc(vremote, jnp.full(n, MSG_DU, I32), homev,
                    jnp.full(n, -1, I32), vtag)

    # write the new block
    upd = do
    l2_tag = s.l2_tag.at[node, si, vic_way].set(
        jnp.where(upd, tag2, s.l2_tag[node, si, vic_way]))
    l2_mig = s.l2_mig.at[node, si, vic_way].set(
        jnp.where(upd, 0, s.l2_mig[node, si, vic_way]))
    l2_last = s.l2_last.at[node, si, vic_way].set(
        jnp.where(upd, -1, s.l2_last[node, si, vic_way]))
    l2_streak = s.l2_streak.at[node, si, vic_way].set(
        jnp.where(upd, 0, s.l2_streak[node, si, vic_way]))

    # new-owner directory update
    homen = dir_home_v(cfg, tag2, s.knob_central)
    nlocal = do & (homen == nid)
    nremote = do & ~nlocal
    desc_dun = Desc(nremote, jnp.full(n, MSG_DU, I32), homen, nid, tag2)

    return L2Install(
        l2_tag, l2_mig, l2_last, l2_streak,
        ok=present | do, did=do,
        touch_set=si, touch_way=vic_way,
        desc_duv=desc_duv, desc_dun=desc_dun,
        dirw_vic=(vtag, vval, vlocal),
        dirw_new=(tag2, nid, nlocal),
        n_local_updates=jnp.sum(vlocal.astype(I32)) + jnp.sum(nlocal.astype(I32)),
        n_drops=jnp.sum(fail.astype(I32)),
    )


class L1Install(NamedTuple):
    l1_tag: jnp.ndarray
    l1_owner: jnp.ndarray
    touch_set: jnp.ndarray
    touch_way: jnp.ndarray
    touch: jnp.ndarray         # mask: a touch happened
    desc_wb: Desc
    n_wb_sent: jnp.ndarray
    n_wb_miss: jnp.ndarray


def install_l1(s: SimState, cfg: SimConfig, ctx: NodeCtx, mask: jnp.ndarray,
               addr: jnp.ndarray, owner: jnp.ndarray) -> L1Install:
    """S3 — masked L1 install with victim write-back."""
    ca = cfg.cache
    n = ctx.node_id.shape[0]
    node = jnp.arange(n, dtype=I32)
    nid = ctx.node_id
    tag1, si, hw, present_any = l1_probe(s, cfg, jnp.where(mask, addr, -1))
    present = mask & present_any
    need = mask & ~present

    tags = s.l1_tag[node, si]
    lru = s.l1_lru[node, si]
    inv = tags < 0
    has_inv = jnp.any(inv, axis=1)
    inv_way = jnp.argmax(inv, axis=1).astype(I32)
    lru_way = jnp.argmin(lru, axis=1).astype(I32)
    vic_way = jnp.where(has_inv, inv_way, lru_way)
    vic_valid = need & ~has_inv
    vtag1 = tags[node, vic_way]
    vowner = s.l1_owner[node, si, vic_way]
    vtag2 = jnp.where(vtag1 >= 0, vtag1 >> (ca.l2_shift - ca.l1_shift), -1)

    # local write-back: does our own L2 still hold the victim's block?
    wb_local = vic_valid & (vowner == nid)
    _, _, l2has = l2_probe(s, cfg, jnp.where(wb_local, vtag2, -1))
    n_wb_miss = jnp.sum((wb_local & ~l2has).astype(I32))
    wb_remote = vic_valid & (vowner >= 0) & (vowner != nid)
    desc_wb = Desc(wb_remote, jnp.full(n, MSG_WB, I32), vowner, nid, vtag2)

    way = jnp.where(present, hw, vic_way)
    w = present | need
    l1_tag = s.l1_tag.at[node, si, way].set(
        jnp.where(w, tag1, s.l1_tag[node, si, way]))
    l1_owner = s.l1_owner.at[node, si, way].set(
        jnp.where(w, owner, s.l1_owner[node, si, way]))
    return L1Install(l1_tag, l1_owner, si, way, w, desc_wb,
                     jnp.sum(wb_remote.astype(I32)), n_wb_miss)


# --------------------------------------------------------------------------
# send-queue commit (S2)
# --------------------------------------------------------------------------

def commit_queue(s: SimState, cfg: SimConfig, descs: List[Desc]):
    """Append descriptors (in slot order = serial enqueue order) to the
    per-node packet ring buffer; whole packets are dropped when full.

    Single batched scatter: descriptor d_i lands at ring offset equal to
    the number of earlier accepted descriptors; rejected/invalid rows are
    routed to the sink slot (index ``send_queue``) so indices never
    collide.  (Perf iteration C1: was 3 sequential full-array scatter
    rounds per phase — 2x the q_desc HBM traffic of the batched form.)
    """
    n = s.q_size.shape[0]
    node = jnp.arange(n, dtype=I32)
    qp = cfg.send_queue
    q_size, pkt_ctr = s.q_size, s.pkt_ctr

    offs, accs, rows = [], [], []
    off = jnp.zeros(n, I32)
    drops = jnp.zeros((), I32)
    for d in descs:
        ok = d.valid & (q_size + off < qp)
        drops = drops + jnp.sum((d.valid & ~ok).astype(I32))
        pkt = (pkt_ctr + off) & (cfg.pkt_wrap - 1)
        rows.append(jnp.stack(
            [d.typ, d.dst, d.osrc, d.tag, pkt,
             FLITS_TABLE[jnp.clip(d.typ, 0, len(FLITS_OF) - 1)]], axis=-1))
        offs.append(off)
        accs.append(ok)
        off = off + ok.astype(I32)

    acc = jnp.stack(accs, axis=1)                       # (N, D)
    pos = jnp.stack([(s.q_head + q_size + o) % qp for o in offs], axis=1)
    pos = jnp.where(acc, pos, qp)                       # sink slot
    row = jnp.stack(rows, axis=1)                       # (N, D, 6)
    # rejected rows land in the sink slot (index qp); it is never read —
    # injection only indexes q_head % qp — so it is left dirty on purpose
    # (zeroing it cost a full q_desc rewrite per commit)
    q_desc = s.q_desc.at[node[:, None], pos].set(row)
    stats = bump(s.stats, "send_drop", drops)
    return s._replace(q_desc=q_desc, q_size=q_size + off,
                      pkt_ctr=pkt_ctr + off, stats=stats)


# --------------------------------------------------------------------------
# phase 1a — inbound completion handlers
# --------------------------------------------------------------------------

#: S14 — worst-case packets a handler may enqueue, by message type
#: (REQ, RA, NACK, DA, DR, DU, WB, B2, MIG_ACK, REQ_FWD)
NEED_TABLE = jnp.asarray([2, 1, 0, 1, 1, 0, 0, 3, 0, 2], I32)


def _l1_install_would_wb(s: SimState, cfg: SimConfig, ctx: NodeCtx,
                         mask: jnp.ndarray, addr: jnp.ndarray) -> jnp.ndarray:
    """Need probe: would :func:`install_l1` send a remote victim
    write-back?  Pure reads — mirrors install_l1's victim selection
    (first invalid way, else LRU) without the install scatters; must stay
    in sync with it (and with ``ref_serial._exact_need``'s RA branch)."""
    node = jnp.arange(addr.shape[0], dtype=I32)
    _, si, _, present_any = l1_probe(s, cfg, jnp.where(mask, addr, -1))
    need_i = mask & ~present_any
    tags = s.l1_tag[node, si]
    has_inv = jnp.any(tags < 0, axis=1)
    lru_way = jnp.argmin(s.l1_lru[node, si], axis=1).astype(I32)
    vowner = s.l1_owner[node, si, lru_way]
    return need_i & ~has_inv & (vowner >= 0) & (vowner != ctx.node_id)


def _l2_install_du_count(s: SimState, cfg: SimConfig, ctx: NodeCtx,
                         mask: jnp.ndarray, tag2: jnp.ndarray) -> jnp.ndarray:
    """Need probe: how many remote directory updates (DU packets) would
    :func:`install_l2` enqueue?  Pure reads — mirrors install_l2's
    victim selection (invalid way, else non-migrating LRU, else fail)
    without the install scatters; must stay in sync with it (and with
    ``ref_serial._exact_need``'s B2 branch)."""
    node = jnp.arange(tag2.shape[0], dtype=I32)
    nid = ctx.node_id
    si, _, present_any = l2_probe(s, cfg, jnp.where(mask, tag2, -1))
    need_i = mask & ~present_any
    tags = s.l2_tag[node, si]
    migf = s.l2_mig[node, si]
    has_inv = jnp.any(tags < 0, axis=1)
    lru_key = s.l2_lru[node, si] + migf * BIG
    lru_way = jnp.argmin(lru_key, axis=1).astype(I32)
    all_mig = jnp.all(migf > 0, axis=1)
    do = need_i & ~(~has_inv & all_mig)           # install fails when every
    vic_valid = do & ~has_inv                     # way is pinned migrating
    vtag = tags[node, lru_way]
    duv = vic_valid & (dir_home_v(cfg, vtag, s.knob_central) != nid)
    dun = do & (dir_home_v(cfg, tag2, s.knob_central) != nid)
    return duv.astype(I32) + dun.astype(I32)


def phase1a(s: SimState, cfg: SimConfig, ctx: NodeCtx) -> SimState:
    n = ctx.node_id.shape[0]
    node = jnp.arange(n, dtype=I32)
    nid = ctx.node_id
    stats = s.stats

    # the handler always serves the *head* of the pending-completion queue
    # (FIFO; depth 1 = the paper's single S14 register)
    head = s.pc[:, 0]
    pc_valid = head[:, P_VALID] > 0
    typ = head[:, P_TYP]
    src = head[:, P_SRC]
    osrc = head[:, P_OSRC]
    tag = head[:, P_TAG]

    p_req = pc_valid & ((typ == MSG_REQ) | (typ == MSG_REQ_FWD))
    p_ra = pc_valid & (typ == MSG_RA)
    p_da = pc_valid & (typ == MSG_DA)
    p_dr = pc_valid & (typ == MSG_DR)
    p_b2 = pc_valid & (typ == MSG_B2)
    p_wb = pc_valid & (typ == MSG_WB)
    p_ack = pc_valid & (typ == MSG_MIG_ACK)

    # shared L2 probe on the completion tag (masked by the head's message
    # type, not by the fire decision — the exact-need gate below must see
    # the probe before deciding whether the handler fires this cycle)
    probe_mask = p_req | p_wb | p_ack
    si, hw, l2hit_any = l2_probe(s, cfg, jnp.where(probe_mask, tag, -1))

    # S14: backpressure — defer until the send queue can hold the response.
    # pc_depth=1 (the paper's single completion register) gates on the
    # worst-case NEED table, bit-identical to the seed semantics.  With a
    # queue (pc_depth > 1) the head is gated on the EXACT number of
    # packets this handler will enqueue — the drain-from-head half of the
    # ejection guarantee: a head whose response actually fits never
    # blocks the queue (the worst-case table could wedge a node whose
    # send queue hovers one slot short of the worst case forever).
    if cfg.pc_depth > 1:
        req_hit_p = p_req & l2hit_any
        mig_ok_p = (req_hit_p & (s.knob_mig > 0) & (osrc != nid)
                    & (s.l2_mig[node, si, hw] == 0))
        streak_p = jnp.where(s.l2_last[node, si, hw] == osrc,
                             s.l2_streak[node, si, hw] + 1, 1)
        trig_p = mig_ok_p & (streak_p >= s.knob_mig_thr)
        ra_ok_p = p_ra & (s.st == ST_WAIT_DATA)
        ra_wb_p = _l1_install_would_wb(s, cfg, ctx, ra_ok_p, s.pend_addr)
        b2_du_p = _l2_install_du_count(s, cfg, ctx, p_b2, tag)
        dr_req_p = p_dr & (s.st == ST_WAIT_DIR) & (osrc >= 0)
        need = (p_req.astype(I32) + trig_p.astype(I32)        # RA/NACK/FWD + B2
                + ra_wb_p.astype(I32)                         # RA victim WB
                + p_da.astype(I32)                            # DR reply
                + dr_req_p.astype(I32)                        # REQ to owner
                + p_b2.astype(I32)                            # MIG_ACK
                + b2_du_p)                                    # install_l2 DUs
    else:
        need = NEED_TABLE[jnp.clip(typ, 0, 9)]
    valid = pc_valid & (s.q_size + need <= cfg.send_queue)
    if cfg.pc_depth > 1:
        # guaranteed drain: a FULL queue must make progress every cycle
        # (its node cannot eject, so it may never get to inject and free
        # send-queue space on its own) — the head fires even without
        # space; response packets that do not fit are dropped whole by
        # commit_queue (send_drop) and recovered by the requester's
        # req_timeout retry.
        pc_full = (jnp.sum((s.pc[:, :, P_VALID] > 0).astype(I32), axis=1)
                   >= cfg.pc_depth)
        valid = valid | (pc_valid & pc_full)

    is_req = valid & p_req
    is_ra = valid & p_ra
    is_nack = valid & (typ == MSG_NACK)
    is_da = valid & p_da
    is_dr = valid & p_dr
    is_du = valid & (typ == MSG_DU)
    is_wb = valid & p_wb
    is_b2 = valid & p_b2
    is_ack = valid & p_ack
    l2hit = (is_req | is_wb | is_ack) & l2hit_any

    d0 = empty_desc(n)
    d1 = empty_desc(n)
    d2 = empty_desc(n)

    st, ctr, imode = s.st, s.ctr, s.install_mode
    l2_tag, l2_mig = s.l2_tag, s.l2_mig
    l2_last, l2_streak = s.l2_last, s.l2_streak
    fwd_tag, fwd_dst, fwd_ptr = s.fwd_tag, s.fwd_dst, s.fwd_ptr
    dir_loc = s.dir_loc

    # ---- REQ / REQ_FWD: remote access service + migration trigger ----
    req_hit = is_req & l2hit
    req_miss = is_req & ~l2hit
    stats = bump(stats, "req_rcvd", is_req)
    stats = bump(stats, "reply_sent", req_hit)
    d0 = merge_desc(d0, Desc(req_hit, jnp.full(n, MSG_RA, I32), osrc, osrc, tag))

    mig_ok = (req_hit & (s.knob_mig > 0) & (osrc != nid)
              & (l2_mig[node, si, hw] == 0))
    streak_new = jnp.where(l2_last[node, si, hw] == osrc,
                           l2_streak[node, si, hw] + 1, 1)
    l2_last = l2_last.at[node, si, hw].set(
        jnp.where(mig_ok, osrc, l2_last[node, si, hw]))
    l2_streak = l2_streak.at[node, si, hw].set(
        jnp.where(mig_ok, streak_new, l2_streak[node, si, hw]))
    trig = mig_ok & (streak_new >= s.knob_mig_thr)
    l2_mig = l2_mig.at[node, si, hw].set(
        jnp.where(trig, 1, l2_mig[node, si, hw]))
    d1 = merge_desc(d1, Desc(trig, jnp.full(n, MSG_B2, I32), osrc, nid, tag))
    stats = bump(stats, "migrations", trig)

    fwd_hm = (fwd_tag == tag[:, None]) & req_miss[:, None]
    fwd_found = jnp.any(fwd_hm, axis=1)
    fwd_to = fwd_dst[node, jnp.argmax(fwd_hm, axis=1)]
    redir = req_miss & fwd_found & (fwd_to >= 0) & (fwd_to != nid)
    trap = req_miss & ~redir
    d0 = merge_desc(d0, Desc(redir, jnp.full(n, MSG_REQ_FWD, I32), fwd_to, osrc, tag))
    d0 = merge_desc(d0, Desc(trap, jnp.full(n, MSG_NACK, I32), osrc, osrc, tag))
    stats = bump(stats, "redirection", redir)
    stats = bump(stats, "trap", trap)

    # ---- RA (data reply) ----
    ra_ok = is_ra & (st == ST_WAIT_DATA)
    stats = bump(stats, "reply_rcvd", ra_ok)
    stats = bump(stats, "stray", is_ra & ~ra_ok)
    ins1 = install_l1(s, cfg, ctx, ra_ok, s.pend_addr, src)
    l1_tag_, l1_owner_ = ins1.l1_tag, ins1.l1_owner
    d0 = merge_desc(d0, ins1.desc_wb)
    stats = bump(stats, "wb_sent", ins1.n_wb_sent)
    stats = bump(stats, "wb_miss", ins1.n_wb_miss)
    st = jnp.where(ra_ok, ST_IDLE, st)

    # ---- NACK (trap reply) ----
    nk_ok = is_nack & (st == ST_WAIT_DATA)
    stats = bump(stats, "stray", is_nack & ~nk_ok)
    st = jnp.where(nk_ok, ST_WAIT_MEM, st)
    ctr = jnp.where(nk_ok, cfg.mem_cycles, ctr)
    imode = jnp.where(nk_ok, INSTALL_L1_ONLY, imode)
    stats = bump(stats, "mem_req", nk_ok)

    # ---- DA (directory lookup at home, S6 reserve-on-miss) ----
    stats = bump(stats, "dir_search", is_da)
    owner0 = dir_read(dir_loc, cfg, tag, is_da)
    reserve = is_da & ((owner0 < 0) | (owner0 == osrc))
    owner_rep = jnp.where(reserve, -1, owner0)
    d0 = merge_desc(d0, Desc(is_da, jnp.full(n, MSG_DR, I32), osrc, owner_rep, tag))

    # ---- DR (directory reply) ----
    dr_ok = is_dr & (st == ST_WAIT_DIR)
    stats = bump(stats, "stray", is_dr & ~dr_ok)
    dr_owner = osrc
    dr_req = dr_ok & (dr_owner >= 0)
    dr_mem = dr_ok & (dr_owner < 0)
    d0 = merge_desc(d0, Desc(dr_req, jnp.full(n, MSG_REQ, I32), dr_owner, nid, tag))
    stats = bump(stats, "req_made", dr_req)
    st = jnp.where(dr_req, ST_WAIT_DATA, st)
    if cfg.pc_depth > 1:   # arm the transaction timeout (see phase1b)
        ctr = jnp.where(dr_req, cfg.req_timeout, ctr)
    st = jnp.where(dr_mem, ST_WAIT_MEM, st)
    ctr = jnp.where(dr_mem, cfg.mem_cycles, ctr)
    imode = jnp.where(dr_mem, INSTALL_L2, imode)
    stats = bump(stats, "mem_req", dr_mem)

    # ---- DU (directory update) ----
    stats = bump(stats, "dir_update", is_du)
    du_cur = dir_read(dir_loc, cfg, tag, is_du)
    du_val = jnp.where(osrc < 0,
                       jnp.where(du_cur == src, -1, du_cur),
                       osrc)

    # ---- WB (L1 victim write-back arriving at the block's L2 home) ----
    wb_hit = is_wb & l2hit
    stats = bump(stats, "wb_rcvd", is_wb)
    stats = bump(stats, "wb_miss", is_wb & ~l2hit)

    # ---- B2 (migration arrival) ----
    stats = bump(stats, "migrations_done", is_b2)
    s_tmp = s._replace(l2_tag=l2_tag, l2_mig=l2_mig, l2_last=l2_last,
                       l2_streak=l2_streak)
    ins2 = install_l2(s_tmp, cfg, ctx, is_b2, tag)
    l2_tag, l2_mig = ins2.l2_tag, ins2.l2_mig
    l2_last, l2_streak = ins2.l2_last, ins2.l2_streak
    d0 = merge_desc(d0, ins2.desc_duv)
    d1 = merge_desc(d1, ins2.desc_dun)
    ack_osrc = jnp.where(ins2.ok, nid, -1)
    d2 = merge_desc(d2, Desc(is_b2, jnp.full(n, MSG_MIG_ACK, I32), src, ack_osrc, tag))
    stats = bump(stats, "dir_update", ins2.n_local_updates)
    stats = bump(stats, "l2_install_drop", ins2.n_drops)

    # ---- MIG_ACK (S13) ----
    ak_succ = is_ack & (osrc >= 0) & l2hit & (l2_mig[node, si, hw] > 0)
    l2_tag = l2_tag.at[node, si, hw].set(
        jnp.where(ak_succ, -1, l2_tag[node, si, hw]))
    l2_mig = l2_mig.at[node, si, hw].set(
        jnp.where(ak_succ, 0, l2_mig[node, si, hw]))
    ak_ins = is_ack & (osrc >= 0)
    p = fwd_ptr % cfg.fwd_entries
    fwd_tag = fwd_tag.at[node, p].set(
        jnp.where(ak_ins, tag, fwd_tag[node, p]))
    fwd_dst = fwd_dst.at[node, p].set(
        jnp.where(ak_ins, osrc, fwd_dst[node, p]))
    fwd_ptr = jnp.where(ak_ins, p + 1, fwd_ptr)
    ak_fail = is_ack & (osrc < 0) & l2hit
    l2_mig = l2_mig.at[node, si, hw].set(
        jnp.where(ak_fail, 0, l2_mig[node, si, hw]))
    l2_streak = l2_streak.at[node, si, hw].set(
        jnp.where(ak_fail, 0, l2_streak[node, si, hw]))

    # ---- directory scatters (disjoint per entry — one handler per node,
    # same entry ⇒ same home ⇒ same node) ----
    mA = (is_da & reserve) | is_du | ins2.dirw_vic[2]
    idxA = jnp.where(is_da & reserve, tag,
                     jnp.where(is_du, tag, ins2.dirw_vic[0]))
    valA = jnp.where(is_da & reserve, osrc,
                     jnp.where(is_du, du_val, ins2.dirw_vic[1]))
    dir_loc = dir_write(dir_loc, cfg, idxA, valA, mA)
    dir_loc = dir_write(dir_loc, cfg, ins2.dirw_new[0], ins2.dirw_new[1],
                        ins2.dirw_new[2])

    # ---- single 1a LRU touch site (serial: ≤1 touch per node in 1a) ----
    l2touch = req_hit | wb_hit | ins2.did
    l1touch = ins1.touch
    any_touch = l2touch | l1touch
    clock = s.lru_clock + any_touch.astype(I32)
    tsi = jnp.where(ins2.did, ins2.touch_set, si)
    twy = jnp.where(ins2.did, ins2.touch_way, hw)
    l2_lru = s.l2_lru.at[node, tsi, twy].set(
        jnp.where(l2touch, clock, s.l2_lru[node, tsi, twy]))
    l1_lru = s.l1_lru.at[node, ins1.touch_set, ins1.touch_way].set(
        jnp.where(l1touch, clock, s.l1_lru[node, ins1.touch_set, ins1.touch_way]))

    s = s._replace(
        st=st, ctr=ctr, install_mode=imode, lru_clock=clock,
        l1_tag=l1_tag_, l1_owner=l1_owner_, l1_lru=l1_lru,
        l2_tag=l2_tag, l2_lru=l2_lru, l2_mig=l2_mig, l2_last=l2_last,
        l2_streak=l2_streak, dir_loc=dir_loc,
        fwd_tag=fwd_tag, fwd_dst=fwd_dst, fwd_ptr=fwd_ptr,
        # pop the served head: shift the queue down one slot (depth 1:
        # this zeroes the register, exactly the old behaviour)
        pc=jnp.where(valid[:, None, None],
                     jnp.concatenate([s.pc[:, 1:],
                                      jnp.zeros_like(s.pc[:, :1])], axis=1),
                     s.pc),
        stats=stats,
    )
    return commit_queue(s, cfg, [d0, d1, d2])


# --------------------------------------------------------------------------
# phase 1b — trace-driven FSM
# --------------------------------------------------------------------------

def _next_addr(s: SimState, cfg: SimConfig):
    m = s.trace.shape[1]
    node = jnp.arange(s.trace.shape[0], dtype=I32)
    ptr = jnp.clip(s.tr_ptr, 0, m - 1)
    # trace is the one leaf widen_state leaves in storage dtype (read-only
    # (N, M) block) — widen after the gather, not the whole array
    a = s.trace[node, ptr].astype(I32)
    exhausted = (s.tr_ptr >= m) | (a < 0)
    return jnp.where(exhausted, -1, a), exhausted


def phase1b(s: SimState, cfg: SimConfig, ctx: NodeCtx) -> SimState:
    n = ctx.node_id.shape[0]
    ca = cfg.cache
    node = jnp.arange(n, dtype=I32)
    nid = ctx.node_id
    stats = s.stats
    st, ctr = s.st, s.ctr

    d0 = empty_desc(n)
    d1 = empty_desc(n)
    d2 = empty_desc(n)

    addr, exhausted = _next_addr(s, cfg)

    # S14: per-state send-queue space requirements gate FSM "fire" points
    space = cfg.send_queue - s.q_size

    # ---- IDLE: consume one trace address ----
    idle = st == ST_IDLE
    go_done = idle & exhausted
    consume = idle & ~exhausted
    tag1, si1, hw1, l1hit_any = l1_probe(s, cfg, jnp.where(consume, addr, -1))
    l1hit = consume & l1hit_any
    l1miss = consume & ~l1hit_any
    stats = bump(stats, "l1_hits", l1hit)
    stats = bump(stats, "l1_misses", l1miss)
    tr_ptr = s.tr_ptr + consume.astype(I32)
    pend_addr = jnp.where(l1miss, addr, s.pend_addr)
    st = jnp.where(go_done, ST_DONE, st)
    st = jnp.where(l1miss, ST_L1_WAIT, st)
    ctr = jnp.where(l1miss, cfg.l1_miss_cycles, ctr)

    # ---- L1_WAIT: countdown then local-L2 probe / directory ----
    l1w = (s.st == ST_L1_WAIT)
    ctr = jnp.where(l1w, ctr - 1, ctr)
    l1w_fire0 = l1w & (ctr <= 0)
    l1w_fire = l1w_fire0 & (space >= 1)
    ctr = jnp.where(l1w_fire0 & ~l1w_fire, 1, ctr)
    tag2 = jnp.where(s.pend_addr >= 0, s.pend_addr >> ca.l2_shift, -1)
    _, _, l2hit_any = l2_probe(s, cfg, jnp.where(l1w_fire, tag2, -1))
    l2hit = l1w_fire & l2hit_any
    l2miss = l1w_fire & ~l2hit_any
    stats = bump(stats, "l2_local_hits", l2hit)
    stats = bump(stats, "l2_local_misses", l2miss)
    st = jnp.where(l2hit, ST_L2_WAIT, st)
    ctr = jnp.where(l2hit, cfg.l2_hit_cycles, ctr)

    home = dir_home_v(cfg, tag2, s.knob_central)
    inline = l2miss & (home == nid)           # S8
    remote = l2miss & ~inline
    stats = bump(stats, "dir_search", inline)
    owner0 = dir_read(s.dir_loc, cfg, tag2, inline)
    inl_req = inline & (owner0 >= 0) & (owner0 != nid)
    inl_mem = inline & ~inl_req
    d0 = merge_desc(d0, Desc(inl_req, jnp.full(n, MSG_REQ, I32), owner0, nid, tag2))
    stats = bump(stats, "req_made", inl_req)
    st = jnp.where(inl_req, ST_WAIT_DATA, st)
    st = jnp.where(inl_mem, ST_WAIT_MEM, st)
    ctr = jnp.where(inl_mem, cfg.mem_cycles, ctr)
    imode = jnp.where(inl_mem, INSTALL_L2, s.install_mode)
    stats = bump(stats, "mem_req", inl_mem)
    dir_loc = dir_write(s.dir_loc, cfg, tag2, nid, inl_mem)   # reserve (S6)

    d0 = merge_desc(d0, Desc(remote, jnp.full(n, MSG_DA, I32), home, nid, tag2))
    st = jnp.where(remote, ST_WAIT_DIR, st)
    if cfg.pc_depth > 1:   # arm the transaction timeout
        ctr = jnp.where(remote | inl_req, cfg.req_timeout, ctr)

    # ---- L2_WAIT: countdown then move block into L1 ----
    l2w = (s.st == ST_L2_WAIT)
    ctr = jnp.where(l2w, ctr - 1, ctr)
    l2w_fire0 = l2w & (ctr <= 0)
    l2w_fire = l2w_fire0 & (space >= 1)
    ctr = jnp.where(l2w_fire0 & ~l2w_fire, 1, ctr)
    si2f, hw2f, l2f_hit = l2_probe(s, cfg, jnp.where(l2w_fire, tag2, -1))
    l2f_touch = l2w_fire & l2f_hit

    # ---- WAIT_MEM: countdown then install ----
    wm = (s.st == ST_WAIT_MEM)
    ctr = jnp.where(wm, ctr - 1, ctr)
    wm_fire0 = wm & (ctr <= 0)
    wm_fire = wm_fire0 & (space >= 3)
    ctr = jnp.where(wm_fire0 & ~wm_fire, 1, ctr)
    wm_wait = wm & ~wm_fire0
    wm_l2 = wm_fire & (s.install_mode == INSTALL_L2)
    wm_l1o = wm_fire & (s.install_mode == INSTALL_L1_ONLY)

    s_mid = s._replace(dir_loc=dir_loc)
    ins2 = install_l2(s_mid, cfg, ctx, wm_l2, tag2)
    d0 = merge_desc(d0, ins2.desc_duv)
    d1 = merge_desc(d1, ins2.desc_dun)
    stats = bump(stats, "dir_update", ins2.n_local_updates)
    stats = bump(stats, "l2_install_drop", ins2.n_drops)
    dir_loc = dir_write(dir_loc, cfg, ins2.dirw_vic[0], ins2.dirw_vic[1],
                        ins2.dirw_vic[2])
    dir_loc = dir_write(dir_loc, cfg, ins2.dirw_new[0], ins2.dirw_new[1],
                        ins2.dirw_new[2])

    # ---- WAIT_DIR / WAIT_DATA transaction timeout (pc_depth > 1 only):
    #      restart with a fresh DA to the tag's home — retransmit-once
    #      recovery for responses the guaranteed drain had to drop; a
    #      stale duplicate response later lands in `stray` ----
    if cfg.pc_depth > 1:
        wt = (s.st == ST_WAIT_DIR) | (s.st == ST_WAIT_DATA)
        ctr = jnp.where(wt, ctr - 1, ctr)
        rt_fire0 = wt & (ctr <= 0)
        rt_fire = rt_fire0 & (space >= 1)
        ctr = jnp.where(rt_fire0 & ~rt_fire, 1, ctr)
        d0 = merge_desc(d0, Desc(rt_fire, jnp.full(n, MSG_DA, I32), home,
                                 nid, tag2))
        st = jnp.where(rt_fire, ST_WAIT_DIR, st)
        ctr = jnp.where(rt_fire, cfg.req_timeout, ctr)

    # ---- hit-under-miss (S7) in WAIT_DIR / WAIT_DATA / counting WAIT_MEM ----
    waiting = (s.st == ST_WAIT_DIR) | (s.st == ST_WAIT_DATA) | wm_wait
    h_addr, h_exh = _next_addr(s._replace(tr_ptr=tr_ptr), cfg)
    h_try = waiting & ~h_exh
    htag1, hsi, hhw, hum_hit_any = l1_probe(s, cfg, jnp.where(h_try, h_addr, -1))
    hum = h_try & hum_hit_any
    stats = bump(stats, "l1_hits", hum)
    tr_ptr = tr_ptr + hum.astype(I32)

    # ---- touch site 2 (first 1b touch: IDLE L1 hit | L2_WAIT L2 touch |
    #      install_l2 new-block touch | hit-under-miss L1 touch) ----
    t2_l1 = l1hit | hum
    t2_l2 = l2f_touch | ins2.did
    t2 = t2_l1 | t2_l2
    clock = s.lru_clock + t2.astype(I32)
    t2_l1_set = jnp.where(l1hit, si1, hsi)
    t2_l1_way = jnp.where(l1hit, hw1, hhw)
    l1_lru = s.l1_lru.at[node, t2_l1_set, t2_l1_way].set(
        jnp.where(t2_l1, clock, s.l1_lru[node, t2_l1_set, t2_l1_way]))
    t2_l2_set = jnp.where(l2f_touch, si2f, ins2.touch_set)
    t2_l2_way = jnp.where(l2f_touch, hw2f, ins2.touch_way)
    l2_lru = s.l2_lru.at[node, t2_l2_set, t2_l2_way].set(
        jnp.where(t2_l2, clock, s.l2_lru[node, t2_l2_set, t2_l2_way]))

    # ---- install_l1 (touch site 3): L2_WAIT refill, WAIT_MEM installs ----
    il1_mask = l2w_fire | wm_fire
    il1_owner = jnp.where(wm_l1o, -1, nid)
    s_mid2 = s._replace(
        l1_lru=l1_lru, l2_lru=l2_lru, lru_clock=clock,
        l2_tag=ins2.l2_tag, l2_mig=ins2.l2_mig, l2_last=ins2.l2_last,
        l2_streak=ins2.l2_streak,
    )
    ins1 = install_l1(s_mid2, cfg, ctx, il1_mask, s.pend_addr, il1_owner)
    d2 = merge_desc(d2, ins1.desc_wb)
    stats = bump(stats, "wb_sent", ins1.n_wb_sent)
    stats = bump(stats, "wb_miss", ins1.n_wb_miss)
    clock = clock + ins1.touch.astype(I32)
    l1_lru = l1_lru.at[node, ins1.touch_set, ins1.touch_way].set(
        jnp.where(ins1.touch, clock, l1_lru[node, ins1.touch_set, ins1.touch_way]))
    st = jnp.where(il1_mask, ST_IDLE, st)

    s = s._replace(
        st=st, ctr=ctr, tr_ptr=tr_ptr, pend_addr=pend_addr,
        install_mode=imode, lru_clock=clock,
        l1_tag=ins1.l1_tag, l1_lru=l1_lru, l1_owner=ins1.l1_owner,
        l2_tag=ins2.l2_tag, l2_lru=l2_lru, l2_mig=ins2.l2_mig,
        l2_last=ins2.l2_last, l2_streak=ins2.l2_streak,
        dir_loc=dir_loc, stats=stats,
    )
    return commit_queue(s, cfg, [d0, d1, d2])
