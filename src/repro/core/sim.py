"""Vectorized simulator driver: the paper's main loop (§7.1/§7.2) in JAX.

The serial version's

    while not finished: Phase1(all); Phase2(all); Phase3(all)

and the GPU version's three-kernel loop both become a single jitted
``cycle_step`` (phases fused by XLA) inside ``lax.while_loop``.

There is ONE driver, :func:`_run_jit`, and it is batched: a solo ``run``
is the batch-of-1 special case of the sweep, so solo runs, batched sweeps
(:mod:`repro.core.sweep`) and the execution-plan layer
(:mod:`repro.core.engine`) all share the same loop, termination predicate,
progress monitors and statistics collection.

Progress monitors (carried inside the compiled loop, per scenario):

* **Livelock** — no *progress* statistic (anything but the pure-motion
  counters ``hops``/``deflections``) changes for
  ``cfg.livelock_window_effective`` consecutive cycles while the scenario
  is unfinished.  This catches the S14 backpressure/ejection-bar cycles
  the paper-faithful ``pc_depth=1`` register admits (flits keep
  circulating — hops keep rising — but nothing retires) without burning
  ``max_cycles``; at the default ``pc_depth`` the pending-completion
  queue's ejection guarantee resolves those cycles and the monitor
  watches them run to completion (docs/architecture.md).
* **Directory saturation** — on centralized-directory scenarios at >= 256
  nodes, evaluated every ``cfg.sat_window`` cycles: at least half the
  nodes sit in WAIT_DIR/WAIT_DATA while fewer than ``num_nodes/2``
  references retired over the window (the paper's node-0 hotspot).

A monitor never changes the cycle-by-cycle semantics of a healthy run —
it only stops early, snapshotting the statistics and a diagnostic
(circulating flits, wait-state counts, node-0 pressure) at the abort
cycle, so aborted results are independent of when the loop actually
exits.
"""
from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .config import ST_DONE, ST_WAIT_DATA, ST_WAIT_DIR, SimConfig
from .cache import phase1a, phase1b
from .noc import phase2, phase3
from .ref_serial import STAT_NAMES
from .state import (F_DST, F_VALID, P_VALID, R_NFL, Geometry, NodeCtx,
                    SimState, fold_stats, init_state, leaf_dtypes,
                    make_geometry, make_node_ctx, narrow_state, stats_totals,
                    widen_state)

__all__ = ["cycle_step", "finished", "run", "stats_list", "ExecAux",
           "VectorSim", "ABORT_LABELS", "diag_counts", "check_cycle_cap",
           "aggregate_stats", "network_health"]

I32 = jnp.int32

#: statistics that witness forward progress.  hops/deflections are excluded:
#: they keep rising while flits merely circulate, which is exactly the
#: livelock signature the monitor must see *through*.
_PROG_IDX = np.asarray([i for i, k in enumerate(STAT_NAMES)
                        if k not in ("hops", "deflections")])

ABORT_NONE, ABORT_LIVELOCK, ABORT_SATURATION = 0, 1, 2
ABORT_LABELS = {ABORT_LIVELOCK: "livelock", ABORT_SATURATION: "dir_saturation"}
_SAT_MIN_NODES = 256


class ExecAux(NamedTuple):
    """Per-scenario abort record returned by the driver next to the state.

    All leaves are ``(B,)`` (or ``()`` for a solo run) except
    ``abort_stats`` which is ``(B, NUM_STATS)``.  ``abort == 0`` means the
    scenario ran to completion or to ``max_cycles`` untouched; the
    remaining fields are then zero and ignored."""

    abort: jnp.ndarray        # 0 none | 1 livelock | 2 dir saturation
    abort_cycle: jnp.ndarray
    abort_stats: jnp.ndarray     # stats LOW-word snapshot at the abort cycle
    abort_stats_hi: jnp.ndarray  # stats HIGH-word snapshot (base-2**30 pair)
    circ: jnp.ndarray         # circulating (in-flight) flits at abort
    wait_dir: jnp.ndarray     # nodes in WAIT_DIR at abort
    wait_data: jnp.ndarray    # nodes in WAIT_DATA at abort
    stalled: jnp.ndarray      # nodes with a backlogged send queue at abort
    dst0: jnp.ndarray         # in-flight flits destined to node 0 at abort


def diag_counts(st: np.ndarray, inp: np.ndarray,
                q_size: np.ndarray) -> Dict[str, np.int32]:
    """Abort-diagnostic counters from host-side state arrays, keyed like
    the corresponding :class:`ExecAux` fields (``circ``, ``wait_dir``,
    ``wait_data``, ``stalled``, ``dst0``).

    Any per-scenario slice shape works — node-flat or grid-shaped — as
    long as ``inp``'s last axis is the flit-field axis; the sharded and
    composed host drivers use this to snapshot one scenario at its abort
    chunk edge, mirroring the in-graph monitor's snapshot."""
    valid = inp[..., F_VALID] > 0
    return dict(
        circ=np.int32(valid.sum()),
        wait_dir=np.int32((st == ST_WAIT_DIR).sum()),
        wait_data=np.int32((st == ST_WAIT_DATA).sum()),
        stalled=np.int32((q_size > 0).sum()),
        dst0=np.int32((valid & (inp[..., F_DST] == 0)).sum()),
    )


class _Mon(NamedTuple):
    prev_prog: jnp.ndarray    # (B, P) progress stats last cycle
    frz: jnp.ndarray          # (B,) consecutive frozen cycles
    refs_anchor: jnp.ndarray  # (B,) sum(tr_ptr) at the last window edge
    aux: ExecAux


def cycle_step(s: SimState, cfg: SimConfig, geo: Geometry,
               ctx: NodeCtx) -> SimState:
    """One simulated cycle = phases 1a, 1b, 2, 3 (S1).

    The phases always compute in int32: under a packed storage layout
    (``cfg.state_dtype_policy``) the state is widened on entry and
    narrowed back on exit, so the loop carry — the persistent footprint —
    stays narrow while phase semantics are untouched.  The cycle boundary
    also folds the low stats word into ``stats_hi`` (base-2**30 pair), so
    counters cannot wrap at 43k nodes x long runs."""
    dtypes = leaf_dtypes(cfg, s.trace.shape[-1])
    s = widen_state(s)
    s = phase1a(s, cfg, ctx)
    s = phase1b(s, cfg, ctx)
    s, arb = phase2(s, cfg, ctx)
    s = phase3(s, cfg, geo, ctx, arb)
    hi, lo = fold_stats(s.stats_hi, s.stats)
    return narrow_state(
        s._replace(cycle=s.cycle + 1, stats=lo, stats_hi=hi), dtypes)


def finished(s: SimState) -> jnp.ndarray:
    """Termination predicate.  Scalar for a solo state; ``(B,)`` for a
    batched sweep state (reductions run over everything but the leading
    scenario axis)."""
    b = s.cycle.ndim                       # 0 solo, 1 batched
    tail = lambda x: tuple(range(b, x.ndim))
    done = jnp.all(s.st == ST_DONE, axis=tail(s.st))
    net_in = s.inp[..., F_VALID] > 0
    net_empty = ~jnp.any(net_in, axis=tail(net_in))
    q_empty = jnp.all(s.q_size == 0, axis=tail(s.q_size))
    rob_nfl = s.rob[..., R_NFL]
    rob_empty = jnp.all(rob_nfl == 0, axis=tail(rob_nfl))
    pc_v = s.pc[..., P_VALID]
    pc_empty = jnp.all(pc_v == 0, axis=tail(pc_v))
    return done & net_empty & q_empty & rob_empty & pc_empty


def _mon_init(s: SimState) -> _Mon:
    zb = jnp.zeros(s.cycle.shape, I32)
    aux = ExecAux(abort=zb, abort_cycle=zb,
                  abort_stats=jnp.zeros_like(s.stats),
                  abort_stats_hi=jnp.zeros_like(s.stats_hi),
                  circ=zb, wait_dir=zb, wait_data=zb, stalled=zb, dst0=zb)
    # tr_ptr may be stored narrow (packed layout): widen before the sum
    return _Mon(prev_prog=s.stats[..., _PROG_IDX], frz=zb,
                refs_anchor=jnp.sum(s.tr_ptr.astype(I32), axis=-1), aux=aux)


def _mon_update(mon: _Mon, st: SimState, active: jnp.ndarray,
                cfg: SimConfig) -> _Mon:
    """Advance the livelock/saturation monitors one cycle (batched).

    Per-cycle cost is kept to the (B, P) progress-stat compare: the O(N)
    saturation reductions run only at ``sat_window`` edges and the O(N)
    diagnostic snapshot only on the (at most one) cycle a monitor fires —
    both behind ``lax.cond`` (their outputs are scalars per scenario, so
    the carry-copy concern that rules out a per-step cond around the main
    loop body does not apply)."""
    n = cfg.num_nodes
    lw = cfg.livelock_window_effective
    sw = cfg.sat_window if n >= _SAT_MIN_NODES else 0

    prog = st.stats[:, _PROG_IDX]
    frz = jnp.where(jnp.all(prog == mon.prev_prog, axis=-1), mon.frz + 1, 0)
    fire_lv = (active & (frz >= lw)) if lw > 0 \
        else jnp.zeros_like(active)

    if sw > 0:
        at_edge = (st.cycle % sw) == 0       # one clock: all-or-none

        def sat_eval(_):
            refs = jnp.sum(st.tr_ptr.astype(I32), axis=-1)
            wd = jnp.sum((st.st == ST_WAIT_DIR).astype(I32), axis=-1)
            wdd = jnp.sum((st.st == ST_WAIT_DATA).astype(I32), axis=-1)
            fire = (active & at_edge & (st.knob_central > 0)
                    & ((wd + wdd) * 2 >= n)
                    & ((refs - mon.refs_anchor) * 2 < n))
            return fire, jnp.where(at_edge, refs, mon.refs_anchor)

        fire_sat, refs_anchor = jax.lax.cond(
            jnp.any(at_edge), sat_eval,
            lambda _: (jnp.zeros_like(active), mon.refs_anchor), None)
    else:
        fire_sat = jnp.zeros_like(active)
        refs_anchor = mon.refs_anchor
    fire_lv = fire_lv & ~fire_sat      # saturation is the sharper diagnosis
    fire = fire_lv | fire_sat

    def snapshot(aux):
        valid = st.inp[..., F_VALID] > 0
        circ = jnp.sum(valid.astype(I32), axis=(-2, -1))
        dst0 = jnp.sum((valid & (st.inp[..., F_DST] == 0)).astype(I32),
                       axis=(-2, -1))
        stalled = jnp.sum((st.q_size > 0).astype(I32), axis=-1)
        wd = jnp.sum((st.st == ST_WAIT_DIR).astype(I32), axis=-1)
        wdd = jnp.sum((st.st == ST_WAIT_DATA).astype(I32), axis=-1)
        snap = lambda new, old: jnp.where(fire, new, old)
        return ExecAux(
            abort=jnp.where(fire, jnp.where(fire_sat, ABORT_SATURATION,
                                            ABORT_LIVELOCK), aux.abort),
            abort_cycle=snap(st.cycle, aux.abort_cycle),
            abort_stats=jnp.where(fire[:, None], st.stats, aux.abort_stats),
            abort_stats_hi=jnp.where(fire[:, None], st.stats_hi,
                                     aux.abort_stats_hi),
            circ=snap(circ, aux.circ),
            wait_dir=snap(wd, aux.wait_dir),
            wait_data=snap(wdd, aux.wait_data),
            stalled=snap(stalled, aux.stalled),
            dst0=snap(dst0, aux.dst0),
        )

    aux = jax.lax.cond(jnp.any(fire), snapshot, lambda a: a, mon.aux)
    return _Mon(prog, frz, refs_anchor, aux)


@functools.partial(jax.jit, static_argnums=(1, 3), donate_argnums=(0,))
def _run_jit(s: SimState, cfg: SimConfig, max_cycles: jnp.ndarray, chunk: int):
    """Drive a state to completion in one compiled loop; returns
    ``(state, ExecAux)``.

    The input state is DONATED: XLA aliases every input buffer to the
    matching output (the loop carry updates in place instead of
    double-buffering the full mesh), and the caller's arrays are dead
    after the call — every caller here rebinds the result.  Use
    :class:`VectorSim` (whose per-step jit does not donate) to keep a
    pre-step state alive.

    The driver is batched (leading scenario axis); a solo state is lifted
    to a batch of one and unlifted on return, so every caller shares one
    code path.  ``cycle_step`` is vmapped and every scenario terminates
    independently.  A finished scenario is NOT frozen with a full-state
    select — stepping a finished state is a semantic no-op on every leaf
    except the clock (all phase masks are false and every statistic bump
    is zero), and keeping the pre-step state alive for a freeze select
    would block XLA's in-place reuse of every large buffer in the loop
    carry.  Instead the loop records each scenario's finish cycle and
    rewrites the per-scenario ``cycle`` leaf at the end, so the returned
    state is bit-identical to B solo runs.  Aborted scenarios (livelock /
    saturation monitors) likewise keep stepping; their reported statistics
    come from the ``ExecAux`` snapshot taken at the abort cycle, so results
    are independent of when the loop exits.
    """
    solo = s.cycle.ndim == 0
    if solo:
        s = jax.tree.map(lambda x: x[None], s)

    geo = make_geometry(cfg.rows, cfg.cols)
    ctx = make_node_ctx(cfg)
    vstep = jax.vmap(lambda st: cycle_step(st, cfg, geo, ctx))

    def step(c):
        st, done, mon = c
        nxt = vstep(st)
        done = jnp.where((done < 0) & finished(nxt), nxt.cycle, done)
        active = (done < 0) & (mon.aux.abort == 0)
        return nxt, done, _mon_update(mon, nxt, active, cfg)

    def alive(done, mon):
        return jnp.any((done < 0) & (mon.aux.abort == 0))

    carry = (s, jnp.full(s.cycle.shape, -1, I32), _mon_init(s))
    if chunk > 1:
        # main loop: whole chunks with NO per-cycle branch (a per-step
        # lax.cond guard costs carry copies); the loop condition keeps
        # whole chunks from overstepping the cycle cap
        def chunk_cond(c):
            st, done, mon = c
            return alive(done, mon) & (st.cycle[0] + chunk <= max_cycles)

        def chunk_body(c):
            c, _ = jax.lax.scan(lambda cc, _: (step(cc), ()), c,
                                None, length=chunk)
            return c

        carry = jax.lax.while_loop(chunk_cond, chunk_body, carry)

    # tail: per-cycle, so an unfinished scenario stops at exactly
    # max_cycles just like the unchunked loop
    def tail_cond(c):
        st, done, mon = c
        return alive(done, mon) & (st.cycle[0] < max_cycles)

    fs, done, mon = jax.lax.while_loop(tail_cond, step, carry)
    aux = mon.aux
    # finished scenarios kept no-op stepping; restore their true clock.
    # aborted scenarios report the abort cycle.
    cyc = jnp.where(done >= 0, done,
                    jnp.where(aux.abort > 0, aux.abort_cycle, fs.cycle))
    fs = fs._replace(cycle=cyc)
    if solo:
        unlift = lambda x: x[0]
        fs = jax.tree.map(unlift, fs)
        aux = jax.tree.map(unlift, aux)
    return fs, aux


def stats_list(s: SimState, aux: ExecAux) -> List[Dict[str, int]]:
    """Per-scenario statistics dicts from a driven state + its ExecAux.

    Healthy scenarios get exactly the classic key set (STAT_NAMES +
    ``cycles`` + ``finished``) — bit-identical to what a solo run always
    produced.  Aborted scenarios report the snapshot taken at the abort
    cycle plus ``aborted`` (label) and the diagnostic counters."""
    stats = np.atleast_2d(stats_totals(s.stats_hi, s.stats))
    cyc = np.atleast_1d(np.asarray(s.cycle))
    fin = np.atleast_1d(np.asarray(finished(s)))
    a = {k: np.atleast_1d(np.asarray(v)) for k, v in aux._asdict().items()}
    a["abort_stats"] = np.atleast_2d(
        stats_totals(aux.abort_stats_hi, aux.abort_stats))
    out = []
    for b in range(cyc.shape[0]):
        code = int(a["abort"][b])
        if code:
            d = {k: int(v) for k, v in zip(STAT_NAMES, a["abort_stats"][b])}
            d["cycles"] = int(a["abort_cycle"][b])
            d["finished"] = 0
            d["aborted"] = ABORT_LABELS[code]
            d["circulating_flits"] = int(a["circ"][b])
            d["wait_dir_nodes"] = int(a["wait_dir"][b])
            d["wait_data_nodes"] = int(a["wait_data"][b])
            d["stalled_queues"] = int(a["stalled"][b])
            d["flits_to_node0"] = int(a["dst0"][b])
        else:
            d = {k: int(v) for k, v in zip(STAT_NAMES, stats[b])}
            d["cycles"] = int(cyc[b])
            d["finished"] = int(bool(fin[b]))
        out.append(d)
    return out


def aggregate_stats(stats: List[Dict[str, int]]) -> Dict[str, int]:
    """Sum the ``STAT_NAMES`` counters over per-scenario ``stats`` dicts
    (as produced by :func:`stats_list` / :func:`run`); ``cycles`` becomes
    the max and ``finished`` the min, so the aggregate reads like one
    worst-case scenario.  Non-counter diagnostic keys are dropped."""
    out = {k: sum(int(d.get(k, 0)) for d in stats) for k in STAT_NAMES}
    out["cycles"] = max((int(d.get("cycles", 0)) for d in stats), default=0)
    out["finished"] = min((int(d.get("finished", 0)) for d in stats),
                          default=0)
    return out


def network_health(stats: Dict[str, int]) -> Dict[str, float]:
    """Derived network-health ratios from one statistics dict ``stats``
    (a solo result or an :func:`aggregate_stats` roll-up) — the
    deflection-routing metrics the literature tracks alongside raw
    throughput (deflection rate, ejection-latency proxy, recovered
    drops):

    * ``deflection_rate`` — deflections per hop: the fraction of routing
      decisions that missed their productive port.
    * ``hops_per_flit`` — average hops each *delivered* flit took.  In a
      bufferless mesh every deflection is a detour, so this proxies
      in-network (ejection) latency without per-flit timestamps.
    * ``deflections_per_flit`` — detours per delivered flit (the same
      latency proxy normalized to the minimal-route floor).
    * ``drops_recovered`` — whole-packet response drops recovered by the
      retransmit path (``send_drop``); ``stray_responses`` — stale
      duplicates absorbed after a transaction restart.
    """
    hops = int(stats.get("hops", 0))
    defl = int(stats.get("deflections", 0))
    flits = int(stats.get("flits_delivered", 0))
    return {
        "deflection_rate": defl / hops if hops else 0.0,
        "hops_per_flit": hops / flits if flits else 0.0,
        "deflections_per_flit": defl / flits if flits else 0.0,
        "drops_recovered": int(stats.get("send_drop", 0)),
        "stray_responses": int(stats.get("stray", 0)),
    }


def check_cycle_cap(cfg: SimConfig, max_cycles: Optional[int]) -> None:
    """Reject a per-call cycle cap above ``cfg.max_cycles`` under the
    packed layout: the narrow dtype map (LRU clocks, flit ages) is sized
    from the config's own cap, so overrunning it could silently wrap
    narrow counters.  The wide layout has int32 headroom everywhere and
    accepts any cap."""
    if (cfg.state_dtype_policy == "packed" and max_cycles is not None
            and max_cycles > cfg.max_cycles):
        raise ValueError(
            f"max_cycles={max_cycles} exceeds cfg.max_cycles="
            f"{cfg.max_cycles}: the packed state layout sizes its narrow "
            "dtypes from the config cap — raise cfg.max_cycles instead")


def run(cfg: SimConfig, trace: np.ndarray, max_cycles: Optional[int] = None,
        chunk: int = 1) -> Union[Dict[str, int], List[Dict[str, int]]]:
    """Run the simulator to completion; returns statistics.

    Args:
        cfg: the simulation config (mesh shape, caches, policies).
        trace: ``(num_nodes, M)`` for a solo run (returns one dict) or
            ``(B, num_nodes, M)`` for a batched run (returns a list of
            dicts; the policy knobs are then shared — use
            :mod:`repro.core.sweep` or :mod:`repro.core.engine` to vary
            them per scenario).
        max_cycles: hard cycle cap (default ``cfg.max_cycles``).
        chunk: simulated cycles per device-loop termination check."""
    check_cycle_cap(cfg, max_cycles)
    s = init_state(cfg, trace)
    solo = s.cycle.ndim == 0
    s, aux = _run_jit(s, cfg, jnp.asarray(max_cycles or cfg.max_cycles,
                                          jnp.int32), chunk)
    out = stats_list(s, aux)
    return out[0] if solo else out


class VectorSim:
    """Step-at-a-time wrapper (used by the equivalence tests to compare
    against :class:`repro.core.ref_serial.SerialSim` cycle by cycle)."""

    def __init__(self, cfg: SimConfig, trace: np.ndarray):
        self.cfg = cfg
        self.geo = make_geometry(cfg.rows, cfg.cols)
        self.ctx = make_node_ctx(cfg)
        self.state = init_state(cfg, trace)
        self._step = jax.jit(
            lambda s: cycle_step(s, cfg, self.geo, self.ctx))

    def step(self) -> None:
        self.state = self._step(self.state)

    def stats(self) -> Dict[str, int]:
        st = stats_totals(self.state.stats_hi, self.state.stats)
        out = {k: int(v) for k, v in zip(STAT_NAMES, st)}
        out["cycles"] = int(self.state.cycle)
        out["finished"] = int(bool(finished(self.state)))
        return out

    def run(self, max_cycles: Optional[int] = None) -> Dict[str, int]:
        limit = max_cycles or self.cfg.max_cycles
        self.state, _ = _run_jit(self.state, self.cfg,
                                 jnp.asarray(limit, jnp.int32), 1)
        return self.stats()
