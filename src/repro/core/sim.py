"""Vectorized simulator driver: the paper's main loop (§7.1/§7.2) in JAX.

The serial version's

    while not finished: Phase1(all); Phase2(all); Phase3(all)

and the GPU version's three-kernel loop both become a single jitted
``cycle_step`` (phases fused by XLA) inside ``lax.while_loop`` — the
CUDA grid barrier between kernels is simply the dataflow between phases.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ST_DONE, SimConfig
from .cache import phase1a, phase1b
from .noc import phase2, phase3
from .ref_serial import STAT_NAMES
from .state import (F_VALID, P_VALID, R_NFL, Geometry, NodeCtx, SimState,
                    init_state, make_geometry, make_node_ctx)

__all__ = ["cycle_step", "finished", "run", "VectorSim"]


def cycle_step(s: SimState, cfg: SimConfig, geo: Geometry,
               ctx: NodeCtx) -> SimState:
    """One simulated cycle = phases 1a, 1b, 2, 3 (S1)."""
    s = phase1a(s, cfg, ctx)
    s = phase1b(s, cfg, ctx)
    s, arb = phase2(s, cfg, ctx)
    s = phase3(s, cfg, geo, ctx, arb)
    return s._replace(cycle=s.cycle + 1)


def finished(s: SimState) -> jnp.ndarray:
    """Termination predicate.  Scalar for a solo state; ``(B,)`` for a
    batched sweep state (reductions run over everything but the leading
    scenario axis)."""
    b = s.cycle.ndim                       # 0 solo, 1 batched
    tail = lambda x: tuple(range(b, x.ndim))
    done = jnp.all(s.st == ST_DONE, axis=tail(s.st))
    net_in = s.inp[..., F_VALID] > 0
    net_empty = ~jnp.any(net_in, axis=tail(net_in))
    q_empty = jnp.all(s.q_size == 0, axis=tail(s.q_size))
    rob_nfl = s.rob[..., R_NFL]
    rob_empty = jnp.all(rob_nfl == 0, axis=tail(rob_nfl))
    pc_v = s.pc[..., P_VALID]
    pc_empty = jnp.all(pc_v == 0, axis=tail(pc_v))
    return done & net_empty & q_empty & rob_empty & pc_empty


@functools.partial(jax.jit, static_argnums=(1, 3))
def _run_jit(s: SimState, cfg: SimConfig, max_cycles: jnp.ndarray,
             chunk: int) -> SimState:
    """Drive a solo OR batched state to completion in one compiled loop.

    Batched (leading scenario axis): ``cycle_step`` is vmapped and every
    scenario terminates independently.  A finished scenario is NOT
    frozen with a full-state select — stepping a finished state is a
    semantic no-op on every leaf except the clock (all phase masks are
    false and every statistic bump is zero), and keeping the pre-step
    state alive for a freeze select would block XLA's in-place reuse of
    every large buffer in the loop carry.  Instead the loop records each
    scenario's finish cycle and rewrites the per-scenario ``cycle`` leaf
    at the end, so the returned state is bit-identical to B solo runs.
    """
    batched = s.cycle.ndim == 1

    geo = make_geometry(cfg.rows, cfg.cols)
    ctx = make_node_ctx(cfg)

    if batched:
        vstep = jax.vmap(lambda st: cycle_step(st, cfg, geo, ctx))

        def step(c):
            st, done = c
            nxt = vstep(st)
            fin = finished(nxt)
            done = jnp.where((done < 0) & fin, nxt.cycle, done)
            return nxt, done

        carry = (s, jnp.full(s.cycle.shape, -1, jnp.int32))
        if chunk > 1:
            # main loop: whole chunks with NO per-cycle branch (a per-step
            # lax.cond guard costs carry copies); the loop condition keeps
            # whole chunks from overstepping the cycle cap
            def chunk_cond(c):
                st, done = c
                return jnp.any(done < 0) & (st.cycle[0] + chunk <= max_cycles)

            def chunk_body(c):
                c, _ = jax.lax.scan(lambda cc, _: (step(cc), ()), c,
                                    None, length=chunk)
                return c

            carry = jax.lax.while_loop(chunk_cond, chunk_body, carry)

        # tail: per-cycle, so an unfinished scenario stops at exactly
        # max_cycles just like a solo run
        def tail_cond(c):
            st, done = c
            return jnp.any(done < 0) & (st.cycle[0] < max_cycles)

        fs, done = jax.lax.while_loop(tail_cond, step, carry)
        # finished scenarios kept no-op stepping; restore their true clock
        return fs._replace(cycle=jnp.where(done >= 0, done, fs.cycle))

    def cond(st):
        return (~finished(st)) & (st.cycle < max_cycles)

    def body(st):
        return cycle_step(st, cfg, geo, ctx)

    if chunk <= 1:
        return jax.lax.while_loop(cond, body, s)

    # chunked: run `chunk` cycles per termination check (fewer host syncs,
    # and the inner scan unrolls into a tighter compiled loop)
    def chunk_body(st):
        def scan_fn(carry, _):
            nxt = jax.lax.cond(cond(carry), body, lambda x: x, carry)
            return nxt, ()
        st, _ = jax.lax.scan(scan_fn, st, None, length=chunk)
        return st

    return jax.lax.while_loop(cond, chunk_body, s)


def run(cfg: SimConfig, trace: np.ndarray, max_cycles: Optional[int] = None,
        chunk: int = 1) -> Dict[str, int]:
    """Run the vectorized simulator to completion; returns statistics."""
    s = init_state(cfg, trace)
    s = _run_jit(s, cfg, jnp.asarray(max_cycles or cfg.max_cycles, jnp.int32),
                 chunk)
    stats = np.asarray(s.stats)
    out = {k: int(v) for k, v in zip(STAT_NAMES, stats)}
    out["cycles"] = int(s.cycle)
    out["finished"] = int(bool(finished(s)))
    return out


class VectorSim:
    """Step-at-a-time wrapper (used by the equivalence tests to compare
    against :class:`repro.core.ref_serial.SerialSim` cycle by cycle)."""

    def __init__(self, cfg: SimConfig, trace: np.ndarray):
        self.cfg = cfg
        self.geo = make_geometry(cfg.rows, cfg.cols)
        self.ctx = make_node_ctx(cfg)
        self.state = init_state(cfg, trace)
        self._step = jax.jit(
            lambda s: cycle_step(s, cfg, self.geo, self.ctx))

    def step(self) -> None:
        self.state = self._step(self.state)

    def stats(self) -> Dict[str, int]:
        st = np.asarray(self.state.stats)
        out = {k: int(v) for k, v in zip(STAT_NAMES, st)}
        out["cycles"] = int(self.state.cycle)
        out["finished"] = int(bool(finished(self.state)))
        return out

    def run(self, max_cycles: Optional[int] = None) -> Dict[str, int]:
        limit = max_cycles or self.cfg.max_cycles
        self.state = _run_jit(self.state, self.cfg,
                              jnp.asarray(limit, jnp.int32), 1)
        return self.stats()
