"""Vectorized simulator driver: the paper's main loop (§7.1/§7.2) in JAX.

The serial version's

    while not finished: Phase1(all); Phase2(all); Phase3(all)

and the GPU version's three-kernel loop both become a single jitted
``cycle_step`` (phases fused by XLA) inside ``lax.while_loop`` — the
CUDA grid barrier between kernels is simply the dataflow between phases.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ST_DONE, SimConfig
from .cache import phase1a, phase1b
from .noc import phase2, phase3
from .ref_serial import STAT_NAMES
from .state import (F_VALID, Geometry, NodeCtx, SimState, init_state,
                    make_geometry, make_node_ctx)

__all__ = ["cycle_step", "finished", "run", "VectorSim"]


def cycle_step(s: SimState, cfg: SimConfig, geo: Geometry,
               ctx: NodeCtx) -> SimState:
    """One simulated cycle = phases 1a, 1b, 2, 3 (S1)."""
    s = phase1a(s, cfg, ctx)
    s = phase1b(s, cfg, ctx)
    s, arb = phase2(s, cfg, ctx)
    s = phase3(s, cfg, geo, ctx, arb)
    return s._replace(cycle=s.cycle + 1)


def finished(s: SimState) -> jnp.ndarray:
    done = jnp.all(s.st == ST_DONE)
    net_empty = ~jnp.any(s.inp[:, :, F_VALID] > 0)
    q_empty = jnp.all(s.q_size == 0)
    rob_empty = jnp.all(s.rob[:, :, 5] == 0)   # R_NFL
    pc_empty = jnp.all(s.pc[:, 0] == 0)
    return done & net_empty & q_empty & rob_empty & pc_empty


@functools.partial(jax.jit, static_argnums=(1, 3))
def _run_jit(s: SimState, cfg: SimConfig, max_cycles: jnp.ndarray,
             chunk: int) -> SimState:
    def cond(st):
        return (~finished(st)) & (st.cycle < max_cycles)

    geo = make_geometry(cfg.rows, cfg.cols)
    ctx = make_node_ctx(cfg)

    def body(st):
        return cycle_step(st, cfg, geo, ctx)

    if chunk <= 1:
        return jax.lax.while_loop(cond, body, s)

    # chunked: run `chunk` cycles per termination check (fewer host syncs,
    # and the inner scan unrolls into a tighter compiled loop)
    def chunk_body(st):
        def scan_fn(carry, _):
            nxt = jax.lax.cond(cond(carry), body, lambda x: x, carry)
            return nxt, ()
        st, _ = jax.lax.scan(scan_fn, st, None, length=chunk)
        return st

    return jax.lax.while_loop(cond, chunk_body, s)


def run(cfg: SimConfig, trace: np.ndarray, max_cycles: Optional[int] = None,
        chunk: int = 1) -> Dict[str, int]:
    """Run the vectorized simulator to completion; returns statistics."""
    s = init_state(cfg, trace)
    s = _run_jit(s, cfg, jnp.asarray(max_cycles or cfg.max_cycles, jnp.int32),
                 chunk)
    stats = np.asarray(s.stats)
    out = {k: int(v) for k, v in zip(STAT_NAMES, stats)}
    out["cycles"] = int(s.cycle)
    out["finished"] = int(bool(finished(s)))
    return out


class VectorSim:
    """Step-at-a-time wrapper (used by the equivalence tests to compare
    against :class:`repro.core.ref_serial.SerialSim` cycle by cycle)."""

    def __init__(self, cfg: SimConfig, trace: np.ndarray):
        self.cfg = cfg
        self.geo = make_geometry(cfg.rows, cfg.cols)
        self.ctx = make_node_ctx(cfg)
        self.state = init_state(cfg, trace)
        self._step = jax.jit(
            lambda s: cycle_step(s, cfg, self.geo, self.ctx))

    def step(self) -> None:
        self.state = self._step(self.state)

    def stats(self) -> Dict[str, int]:
        st = np.asarray(self.state.stats)
        out = {k: int(v) for k, v in zip(STAT_NAMES, st)}
        out["cycles"] = int(self.state.cycle)
        out["finished"] = int(bool(finished(self.state)))
        return out

    def run(self, max_cycles: Optional[int] = None) -> Dict[str, int]:
        limit = max_cycles or self.cfg.max_cycles
        self.state = _run_jit(self.state, self.cfg,
                              jnp.asarray(limit, jnp.int32), 1)
        return self.stats()
