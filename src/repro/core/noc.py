"""Vectorized phases 2 & 3: bufferless deflection routing (paper §4, §6.2.1).

Phase 2 (arbitration): per router — eject the oldest deliverable flit (S11),
optionally admit an injection flit (S12), then assign output ports in
age-priority order with PMDR preference lists (S9), deflecting losers.
The per-router age sort is a branch-free greedy loop over 5 candidate slots
evaluated for all routers at once (the TPU-native form of the paper's
"Priority Sort" block, Fig. 3).

Phase 3 (transfer): a pure gather — input port p of node n reads the
opposite output port of its neighbour in direction p.  This gather is the
only cross-node dataflow in the whole simulator; the sharded version
replaces it with a tile-local shift + ``ppermute`` halo exchange
(:mod:`repro.core.sharded`), sharing `deliver` for the ROB/completion step.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

from .config import NUM_PORTS, SimConfig
from .state import (
    F_AGE, F_DST, F_FID, F_NFL, F_OSRC, F_PKT, F_SRC, F_TAG, F_TYP, F_VALID,
    NUM_F, Q_DST, Q_NFL, Q_OSRC, Q_PKT, Q_TAG, Q_TYP,
    R_CNT, R_NFL, R_OSRC, R_PKT, R_SRC, R_TAG, R_TYP,
    P_OSRC, P_SRC, P_TAG, P_TYP, P_VALID,
    Geometry, NodeCtx, SimState, bump,
)

I32 = jnp.int32
#: larger than any node id or packet counter (pkt wraps at 2**30)
BIG = jnp.asarray(1 << 30, I32)


class ArbResult(NamedTuple):
    out: jnp.ndarray        # (Nl, 4, NUM_F) outgoing flits (age already bumped)
    ej_port: jnp.ndarray    # (Nl,)
    has_ej: jnp.ndarray     # (Nl,) bool
    n_deflected: jnp.ndarray
    n_injected: jnp.ndarray


def rob_accepts(s: SimState, flits: jnp.ndarray) -> jnp.ndarray:
    """S10 vectorized: (Nl, P) bool — can each flit be ejected into the ROB."""
    nfl = flits[..., F_NFL]
    src = flits[..., F_SRC]
    pkt = flits[..., F_PKT]
    rob_valid = s.rob[:, :, R_NFL] > 0                      # (Nl, K)
    m = (rob_valid[:, None, :]
         & (s.rob[:, None, :, R_SRC] == src[:, :, None])
         & (s.rob[:, None, :, R_PKT] == pkt[:, :, None]))   # (Nl, P, K)
    has_match = jnp.any(m, axis=-1)
    has_free = jnp.any(~rob_valid, axis=-1)
    return (nfl == 1) | has_match | has_free[:, None]


def phase2(s: SimState, cfg: SimConfig, ctx: NodeCtx) -> Tuple[SimState, ArbResult]:
    n = ctx.node_id.shape[0]
    node = jnp.arange(n, dtype=I32)
    nid = ctx.node_id
    vp = ctx.valid_port
    r = ctx.node_r
    c = ctx.node_c

    inp = s.inp
    valid_in = inp[:, :, F_VALID] > 0

    # ---- ejection (S11): oldest (age desc, port asc) deliverable flit.
    #      S14 + ejection guarantee (pc_depth > 1): with an *empty*
    #      pending-completion queue any deliverable flit may eject (the
    #      paper's behaviour); once the queue is occupied, only flits aged
    #      past the guaranteed-ejection threshold (knob_ej_age) eject —
    #      into spare queue capacity while a slot is free, and into a free
    #      ROB slot (buffered ejection: the completion *parks* and is
    #      promoted into the queue as it drains, see `deliver`) when the
    #      queue is full.  Parking is what breaks the S14 livelock: an
    #      ejection frees an input port, which is the only thing that lets
    #      a saturated node inject, drain its send queue and un-defer its
    #      completion handler.  pc_depth=1 keeps the paper's exact
    #      single-register bar (no ejection while occupied). ----
    acc = rob_accepts(s, inp)
    pc_cnt = jnp.sum((s.pc[:, :, P_VALID] > 0).astype(I32), axis=1)
    pc_empty = pc_cnt == 0
    if cfg.pc_depth > 1:
        pc_has_slot = pc_cnt < cfg.pc_depth
        rob_free = jnp.any(s.rob[:, :, R_NFL] == 0, axis=1)
        # single-flit packets need a free ROB slot to park in; a
        # completing multi-flit packet parks in its own (matched) slot
        park_ok = (inp[:, :, F_NFL] > 1) | rob_free[:, None]
        old_enough = inp[:, :, F_AGE] >= s.knob_ej_age
        ej_ok = (pc_empty[:, None]
                 | (old_enough & (pc_has_slot[:, None] | park_ok)))
    else:
        ej_ok = pc_empty[:, None]
    want_ej = (valid_in & (inp[:, :, F_DST] == nid[:, None]) & acc & ej_ok)
    ej_key = jnp.where(want_ej,
                       inp[:, :, F_AGE] * 4 + (3 - jnp.arange(4, dtype=I32)),
                       -1)
    ej_port = jnp.argmax(ej_key, axis=1).astype(I32)
    has_ej = jnp.max(ej_key, axis=1) >= 0
    is_ej = (jnp.arange(4, dtype=I32)[None, :] == ej_port[:, None]) & has_ej[:, None]
    remaining = valid_in & ~is_ej

    # ---- injection (S12) ----
    n_rem = jnp.sum(remaining.astype(I32), axis=1)
    n_vp = jnp.sum(vp.astype(I32), axis=1)
    qp = cfg.send_queue
    head = s.q_desc[node, s.q_head % qp]                     # (Nl, 6)
    can_inj = (s.q_size > 0) & (n_rem < n_vp)
    inj = jnp.stack([
        can_inj.astype(I32), jnp.zeros(n, I32), nid, head[:, Q_DST],
        head[:, Q_OSRC], head[:, Q_TYP], head[:, Q_TAG], head[:, Q_PKT],
        s.q_fid, head[:, Q_NFL],
    ], axis=-1)

    cand = jnp.concatenate(
        [jnp.where(remaining[:, :, None], inp, 0), inj[:, None, :]], axis=1)
    cand_valid = cand[:, :, F_VALID] > 0                     # (Nl, 5)

    # ---- age-priority arbitration (paper Fig. 3 "Priority Sort" + port
    #      selection) — shared oracle / Pallas kernel, see repro.kernels ----
    from repro.kernels import ops as kops
    dst = cand[:, :, F_DST]
    dst_r = jnp.where(dst >= 0, dst // cfg.cols, 0)
    dst_c = jnp.where(dst >= 0, dst % cfg.cols, 0)
    dr_ = dst_r - r[:, None]
    dc_ = dst_c - c[:, None]
    ports = jnp.arange(4, dtype=I32)
    wanted_eject = cand_valid & (dst == nid[:, None])
    assigned, deflect = kops.arbitrate(
        cand[:, :, F_AGE], cand_valid, wanted_eject, dc_, dr_, vp,
        backend="pallas" if cfg.use_pallas_router else "ref")

    # ---- scatter candidates to their output ports (ports are distinct) ----
    new_age = cand[:, :, F_AGE] + deflect.astype(I32)
    cand = cand.at[:, :, F_AGE].set(new_age)
    oh = ((assigned[:, :, None] == ports[None, None, :])
          & cand_valid[:, :, None])                          # (Nl, 5, 4)
    out = jnp.einsum("nsp,nsf->npf", oh.astype(I32), cand)
    out = out.at[:, :, F_VALID].set(jnp.any(oh, axis=1).astype(I32))

    # ---- pop the send queue on injection ----
    injected = can_inj
    q_fid = s.q_fid + injected.astype(I32)
    pkt_done = injected & (q_fid >= head[:, Q_NFL])
    q_head = jnp.where(pkt_done, (s.q_head + 1) % qp, s.q_head)
    q_size = jnp.where(pkt_done, s.q_size - 1, s.q_size)
    q_fid = jnp.where(pkt_done, 0, q_fid)

    stats = bump(s.stats, "injected", injected)
    n_defl = jnp.sum((deflect & cand_valid).astype(I32))
    stats = bump(stats, "deflections", n_defl)
    s = s._replace(q_head=q_head, q_size=q_size, q_fid=q_fid, stats=stats)
    return s, ArbResult(out, ej_port, has_ej, n_defl, jnp.sum(injected.astype(I32)))


def transfer_global(cfg: SimConfig, geo: Geometry, out: jnp.ndarray) -> jnp.ndarray:
    """Single-device phase-3 transfer: global neighbour gather."""
    vp = jnp.asarray(geo.valid_port)
    gn = jnp.asarray(geo.gather_node)                        # (N, 4)
    gp = jnp.asarray(geo.gather_port)                        # (4,)
    moved = out[gn, gp[None, :]]                             # (N, 4, F)
    return jnp.where(vp[:, :, None], moved, 0)


def deliver(s: SimState, cfg: SimConfig, ctx: NodeCtx, arb: ArbResult,
            inp_next: jnp.ndarray) -> SimState:
    """Shared phase-3 tail: hop stats, ejection into ROB, completions.

    Per-node order (identical in :class:`repro.core.ref_serial.SerialSim`):

    1. *Promotion* — if the pending-completion queue has a free slot and
       the ROB holds a parked completion (a slot whose count reached its
       flit total while the queue was full), the parked completion with
       the smallest ``(src, pkt)`` moves to the queue tail and its ROB
       slot is freed.
    2. *Ejected flit* — a single-flit packet (or the flit completing a
       multi-flit packet) becomes a pending completion: appended at the
       queue tail when a slot is free, otherwise *parked* in the ROB
       (its own slot for multi-flit packets; a fresh slot for singles —
       phase2's ejection gate guaranteed one exists).

    At ``pc_depth=1`` nothing ever parks (phase2 only ejects into an
    empty queue), so both steps reduce to the seed's single-register
    behaviour bit-identically.
    """
    n = ctx.node_id.shape[0]
    node = jnp.arange(n, dtype=I32)
    depth = cfg.pc_depth

    stats = bump(s.stats, "hops", arb.out[:, :, F_VALID])

    # ---- promotion: oldest parked completion -> pending-queue tail ----
    rob = s.rob
    rob_valid = rob[:, :, R_NFL] > 0
    pc_cnt = jnp.sum((s.pc[:, :, P_VALID] > 0).astype(I32), axis=1)
    parked = rob_valid & (rob[:, :, R_CNT] >= rob[:, :, R_NFL])
    # deterministic, model-independent pick: smallest (src, pkt).  pkt is
    # a per-source counter, so the pair is unique among parked slots.
    src_k = jnp.where(parked, rob[:, :, R_SRC], BIG)
    min_src = jnp.min(src_k, axis=1)
    pkt_k = jnp.where(parked & (rob[:, :, R_SRC] == min_src[:, None]),
                      rob[:, :, R_PKT], BIG)
    psel = jnp.argmin(pkt_k, axis=1).astype(I32)
    can_prom = jnp.any(parked, axis=1) & (pc_cnt < depth)
    prow = rob[node, psel]
    prom_pc = jnp.stack([jnp.ones(n, I32), prow[:, R_TYP], prow[:, R_SRC],
                         prow[:, R_OSRC], prow[:, R_TAG]], axis=-1)
    tail0 = jnp.clip(pc_cnt, 0, depth - 1)
    pc = s.pc.at[node, tail0].set(
        jnp.where(can_prom[:, None], prom_pc, s.pc[node, tail0]))
    rob = rob.at[node, psel].set(jnp.where(can_prom[:, None], 0, prow))
    pc_cnt = pc_cnt + can_prom.astype(I32)

    # ---- ejection into ROB / pending queue ----
    f = s.inp[node, arb.ej_port]                             # (Nl, F) pre-arb flit
    he = arb.has_ej
    stats = bump(stats, "flits_delivered", he)
    single = he & (f[:, F_NFL] == 1)
    multi = he & (f[:, F_NFL] > 1)

    rob_valid = rob[:, :, R_NFL] > 0                         # post promotion
    m = (rob_valid & (rob[:, :, R_SRC] == f[:, None, F_SRC])
         & (rob[:, :, R_PKT] == f[:, None, F_PKT]))          # (Nl, K)
    has_match = jnp.any(m, axis=1)
    match_idx = jnp.argmax(m, axis=1).astype(I32)
    free_idx = jnp.argmax(~rob_valid, axis=1).astype(I32)
    slot = jnp.where(has_match, match_idx, free_idx)
    newslot = multi & ~has_match
    init_row = jnp.stack([f[:, F_SRC], f[:, F_PKT], f[:, F_TYP], f[:, F_TAG],
                          f[:, F_OSRC], f[:, F_NFL], jnp.zeros(n, I32)], axis=-1)
    cur = rob[node, slot]
    row = jnp.where(newslot[:, None], init_row, cur)
    cnt = row[:, R_CNT] + multi.astype(I32)
    row = row.at[:, R_CNT].set(cnt)
    complete_m = multi & (cnt >= row[:, R_NFL])
    full_row = row                    # snapshot before the zeroing below

    completion = single | complete_m
    to_pc = completion & (pc_cnt < depth)
    to_park = completion & ~to_pc
    # a completed slot is freed when its completion enters the queue, and
    # kept (count == total: the "parked" marker) when the queue is full
    row = jnp.where((complete_m & ~to_park)[:, None], 0, row)
    rob = rob.at[node, slot].set(jnp.where(multi[:, None], row, cur))

    # park a single-flit completion in a fresh slot (guaranteed free by
    # phase2's ejection gate)
    rob_valid2 = rob[:, :, R_NFL] > 0
    park_idx = jnp.argmax(~rob_valid2, axis=1).astype(I32)
    park_row = jnp.stack([f[:, F_SRC], f[:, F_PKT], f[:, F_TYP], f[:, F_TAG],
                          f[:, F_OSRC], jnp.ones(n, I32), jnp.ones(n, I32)],
                         axis=-1)
    single_park = single & to_park
    rob = rob.at[node, park_idx].set(
        jnp.where(single_park[:, None], park_row, rob[node, park_idx]))

    row_pc = jnp.stack([
        to_pc.astype(I32),
        jnp.where(single, f[:, F_TYP], full_row[:, R_TYP]),
        jnp.where(single, f[:, F_SRC], full_row[:, R_SRC]),
        jnp.where(single, f[:, F_OSRC], full_row[:, R_OSRC]),
        jnp.where(single, f[:, F_TAG], full_row[:, R_TAG]),
    ], axis=-1)
    row_pc = row_pc * to_pc[:, None].astype(I32)
    tail = jnp.clip(pc_cnt, 0, depth - 1)
    pc = pc.at[node, tail].set(
        jnp.where(to_pc[:, None], row_pc, pc[node, tail]))

    return s._replace(inp=inp_next, rob=rob, pc=pc, stats=stats)


def phase3(s: SimState, cfg: SimConfig, geo: Geometry, ctx: NodeCtx,
           arb: ArbResult) -> SimState:
    return deliver(s, cfg, ctx, arb, transfer_global(cfg, geo, arb.out))
