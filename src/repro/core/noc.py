"""Vectorized phases 2 & 3: bufferless deflection routing (paper §4, §6.2.1).

Phase 2 (arbitration): per router — eject the oldest deliverable flit (S11),
optionally admit an injection flit (S12), then assign output ports in
age-priority order with PMDR preference lists (S9), deflecting losers.
The per-router age sort is a branch-free greedy loop over 5 candidate slots
evaluated for all routers at once (the TPU-native form of the paper's
"Priority Sort" block, Fig. 3).

Phase 3 (transfer): a pure gather — input port p of node n reads the
opposite output port of its neighbour in direction p.  This gather is the
only cross-node dataflow in the whole simulator; the sharded version
replaces it with a tile-local shift + ``ppermute`` halo exchange
(:mod:`repro.core.sharded`), sharing `deliver` for the ROB/completion step.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

from .config import NUM_PORTS, SimConfig
from .state import (
    F_AGE, F_DST, F_FID, F_NFL, F_OSRC, F_PKT, F_SRC, F_TAG, F_TYP, F_VALID,
    NUM_F, Q_DST, Q_NFL, Q_OSRC, Q_PKT, Q_TAG, Q_TYP,
    R_CNT, R_NFL, R_OSRC, R_PKT, R_SRC, R_TAG, R_TYP,
    P_OSRC, P_SRC, P_TAG, P_TYP, P_VALID,
    Geometry, NodeCtx, SimState, bump,
)

I32 = jnp.int32


class ArbResult(NamedTuple):
    out: jnp.ndarray        # (Nl, 4, NUM_F) outgoing flits (age already bumped)
    ej_port: jnp.ndarray    # (Nl,)
    has_ej: jnp.ndarray     # (Nl,) bool
    n_deflected: jnp.ndarray
    n_injected: jnp.ndarray


def rob_accepts(s: SimState, flits: jnp.ndarray) -> jnp.ndarray:
    """S10 vectorized: (Nl, P) bool — can each flit be ejected into the ROB."""
    nfl = flits[..., F_NFL]
    src = flits[..., F_SRC]
    pkt = flits[..., F_PKT]
    rob_valid = s.rob[:, :, R_NFL] > 0                      # (Nl, K)
    m = (rob_valid[:, None, :]
         & (s.rob[:, None, :, R_SRC] == src[:, :, None])
         & (s.rob[:, None, :, R_PKT] == pkt[:, :, None]))   # (Nl, P, K)
    has_match = jnp.any(m, axis=-1)
    has_free = jnp.any(~rob_valid, axis=-1)
    return (nfl == 1) | has_match | has_free[:, None]


def phase2(s: SimState, cfg: SimConfig, ctx: NodeCtx) -> Tuple[SimState, ArbResult]:
    n = ctx.node_id.shape[0]
    node = jnp.arange(n, dtype=I32)
    nid = ctx.node_id
    vp = ctx.valid_port
    r = ctx.node_r
    c = ctx.node_c

    inp = s.inp
    valid_in = inp[:, :, F_VALID] > 0

    # ---- ejection (S11): oldest (age desc, port asc) deliverable flit;
    #      S14: paused while the pending-completion register is occupied ----
    acc = rob_accepts(s, inp)
    pc_free = (s.pc[:, P_VALID] == 0)
    want_ej = (valid_in & (inp[:, :, F_DST] == nid[:, None]) & acc
               & pc_free[:, None])
    ej_key = jnp.where(want_ej,
                       inp[:, :, F_AGE] * 4 + (3 - jnp.arange(4, dtype=I32)),
                       -1)
    ej_port = jnp.argmax(ej_key, axis=1).astype(I32)
    has_ej = jnp.max(ej_key, axis=1) >= 0
    is_ej = (jnp.arange(4, dtype=I32)[None, :] == ej_port[:, None]) & has_ej[:, None]
    remaining = valid_in & ~is_ej

    # ---- injection (S12) ----
    n_rem = jnp.sum(remaining.astype(I32), axis=1)
    n_vp = jnp.sum(vp.astype(I32), axis=1)
    qp = cfg.send_queue
    head = s.q_desc[node, s.q_head % qp]                     # (Nl, 6)
    can_inj = (s.q_size > 0) & (n_rem < n_vp)
    inj = jnp.stack([
        can_inj.astype(I32), jnp.zeros(n, I32), nid, head[:, Q_DST],
        head[:, Q_OSRC], head[:, Q_TYP], head[:, Q_TAG], head[:, Q_PKT],
        s.q_fid, head[:, Q_NFL],
    ], axis=-1)

    cand = jnp.concatenate(
        [jnp.where(remaining[:, :, None], inp, 0), inj[:, None, :]], axis=1)
    cand_valid = cand[:, :, F_VALID] > 0                     # (Nl, 5)

    # ---- age-priority arbitration (paper Fig. 3 "Priority Sort" + port
    #      selection) — shared oracle / Pallas kernel, see repro.kernels ----
    from repro.kernels import ops as kops
    dst = cand[:, :, F_DST]
    dst_r = jnp.where(dst >= 0, dst // cfg.cols, 0)
    dst_c = jnp.where(dst >= 0, dst % cfg.cols, 0)
    dr_ = dst_r - r[:, None]
    dc_ = dst_c - c[:, None]
    ports = jnp.arange(4, dtype=I32)
    wanted_eject = cand_valid & (dst == nid[:, None])
    assigned, deflect = kops.arbitrate(
        cand[:, :, F_AGE], cand_valid, wanted_eject, dc_, dr_, vp,
        backend="pallas" if getattr(cfg, "use_pallas_router", False) else "ref")

    # ---- scatter candidates to their output ports (ports are distinct) ----
    new_age = cand[:, :, F_AGE] + deflect.astype(I32)
    cand = cand.at[:, :, F_AGE].set(new_age)
    oh = ((assigned[:, :, None] == ports[None, None, :])
          & cand_valid[:, :, None])                          # (Nl, 5, 4)
    out = jnp.einsum("nsp,nsf->npf", oh.astype(I32), cand)
    out = out.at[:, :, F_VALID].set(jnp.any(oh, axis=1).astype(I32))

    # ---- pop the send queue on injection ----
    injected = can_inj
    q_fid = s.q_fid + injected.astype(I32)
    pkt_done = injected & (q_fid >= head[:, Q_NFL])
    q_head = jnp.where(pkt_done, (s.q_head + 1) % qp, s.q_head)
    q_size = jnp.where(pkt_done, s.q_size - 1, s.q_size)
    q_fid = jnp.where(pkt_done, 0, q_fid)

    stats = bump(s.stats, "injected", injected)
    n_defl = jnp.sum((deflect & cand_valid).astype(I32))
    stats = bump(stats, "deflections", n_defl)
    s = s._replace(q_head=q_head, q_size=q_size, q_fid=q_fid, stats=stats)
    return s, ArbResult(out, ej_port, has_ej, n_defl, jnp.sum(injected.astype(I32)))


def transfer_global(cfg: SimConfig, geo: Geometry, out: jnp.ndarray) -> jnp.ndarray:
    """Single-device phase-3 transfer: global neighbour gather."""
    vp = jnp.asarray(geo.valid_port)
    gn = jnp.asarray(geo.gather_node)                        # (N, 4)
    gp = jnp.asarray(geo.gather_port)                        # (4,)
    moved = out[gn, gp[None, :]]                             # (N, 4, F)
    return jnp.where(vp[:, :, None], moved, 0)


def deliver(s: SimState, cfg: SimConfig, ctx: NodeCtx, arb: ArbResult,
            inp_next: jnp.ndarray) -> SimState:
    """Shared phase-3 tail: hop stats, ejection into ROB, completions."""
    n = ctx.node_id.shape[0]
    node = jnp.arange(n, dtype=I32)

    stats = bump(s.stats, "hops", arb.out[:, :, F_VALID])

    # ---- ejection into ROB / pending register ----
    f = s.inp[node, arb.ej_port]                             # (Nl, F) pre-arb flit
    he = arb.has_ej
    stats = bump(stats, "flits_delivered", he)
    single = he & (f[:, F_NFL] == 1)
    multi = he & (f[:, F_NFL] > 1)

    rob = s.rob
    rob_valid = rob[:, :, R_NFL] > 0
    m = (rob_valid & (rob[:, :, R_SRC] == f[:, None, F_SRC])
         & (rob[:, :, R_PKT] == f[:, None, F_PKT]))          # (Nl, K)
    has_match = jnp.any(m, axis=1)
    match_idx = jnp.argmax(m, axis=1).astype(I32)
    free_idx = jnp.argmax(~rob_valid, axis=1).astype(I32)
    slot = jnp.where(has_match, match_idx, free_idx)
    newslot = multi & ~has_match
    init_row = jnp.stack([f[:, F_SRC], f[:, F_PKT], f[:, F_TYP], f[:, F_TAG],
                          f[:, F_OSRC], f[:, F_NFL], jnp.zeros(n, I32)], axis=-1)
    cur = rob[node, slot]
    row = jnp.where(newslot[:, None], init_row, cur)
    cnt = row[:, R_CNT] + multi.astype(I32)
    row = row.at[:, R_CNT].set(cnt)
    complete_m = multi & (cnt >= row[:, R_NFL])
    # a completed slot is freed (zeroed)
    full_row = jnp.where(newslot[:, None], init_row, cur)
    full_row = full_row.at[:, R_CNT].set(cnt)
    row = jnp.where(complete_m[:, None], 0, row)
    rob = rob.at[node, slot].set(jnp.where(multi[:, None], row, cur))

    pc_valid = single | complete_m
    pc = jnp.stack([
        pc_valid.astype(I32),
        jnp.where(single, f[:, F_TYP], full_row[:, R_TYP]),
        jnp.where(single, f[:, F_SRC], full_row[:, R_SRC]),
        jnp.where(single, f[:, F_OSRC], full_row[:, R_OSRC]),
        jnp.where(single, f[:, F_TAG], full_row[:, R_TAG]),
    ], axis=-1)
    pc = pc * pc_valid[:, None].astype(I32)
    # S14: preserve an occupied register (its node was barred from ejecting)
    pc = jnp.where(pc_valid[:, None], pc, s.pc)

    return s._replace(inp=inp_next, rob=rob, pc=pc, stats=stats)


def phase3(s: SimState, cfg: SimConfig, geo: Geometry, ctx: NodeCtx,
           arb: ArbResult) -> SimState:
    return deliver(s, cfg, ctx, arb, transfer_global(cfg, geo, arb.out))
