"""deepseek-coder-33b: 62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256
[arXiv:2401.14196; llama-arch]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense", n_layers=62, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=19200, vocab=32256, rope_theta=100_000.0,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-coder-33b-reduced", n_layers=2, d_model=56,
        n_heads=4, n_kv_heads=2, d_ff=96, vocab=256, max_seq=128)
