"""Architecture registry: ``get(arch_id)`` and ``reduced(arch_id)``.

Each assigned architecture lives in its own module (``yi_6b.py`` …) with
the exact published config; ``reduced()`` returns a tiny same-family config
for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS = [
    "yi-6b",
    "deepseek-coder-33b",
    "tinyllama-1.1b",
    "qwen2-0.5b",
    "qwen2-moe-a2.7b",
    "moonshot-v1-16b-a3b",
    "llama-3.2-vision-11b",
    "mamba2-130m",
    "whisper-small",
    "hymba-1.5b",
]


def _module(arch_id: str):
    return importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))


def get(arch_id: str) -> ModelConfig:
    if arch_id == "noc-sim":
        raise ValueError("noc-sim is configured via repro.core.config")
    return _module(arch_id).CONFIG


def reduced(arch_id: str) -> ModelConfig:
    return _module(arch_id).reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get(a) for a in ARCH_IDS}
