"""qwen2-0.5b: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936,
QKV bias [arXiv:2407.10671]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense", n_layers=24, d_model=896, n_heads=14,
    n_kv_heads=2, d_ff=4864, vocab=151936, qkv_bias=True,
    tie_embeddings=True, rope_theta=1_000_000.0,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2-0.5b-reduced", n_layers=2, d_model=56, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, max_seq=128)
