"""whisper-small: enc-dec 12L+12L d_model=768 12H d_ff=3072 vocab=51865 —
conv/mel frontend STUB: input_specs() supplies 1500 frame embeddings
[arXiv:2212.04356]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51865, encoder_layers=12,
    n_audio_frames=1500, max_seq=32768,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-small-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=256, encoder_layers=2,
        n_audio_frames=32, max_seq=128)
