"""hymba-1.5b: 32L d_model=1600 25H (GQA kv=5) d_ff=5504, parallel
attn+mamba heads, ssm_state=16; sliding-window attention with 3 global
full-attention layers (first/middle/last) [arXiv:2411.13676]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, d_head=64, d_ff=5504, vocab=32001,
    ssm_state=16, ssm_d_head=64, window=1024, global_layers=(0, 15, 31),
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="hymba-1.5b-reduced", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=256, ssm_state=8,
        ssm_d_head=16, window=32, global_layers=(0, 3), max_seq=128)
