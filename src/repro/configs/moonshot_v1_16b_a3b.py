"""moonshot-v1-16b-a3b: 48L d_model=2048 16H (kv=16) d_ff(expert)=1408
vocab=163840, MoE 64 experts top-6 [hf:moonshotai/Moonlight-16B-A3B]

The assignment specifies 64 routed experts, top-6 (no shared experts listed;
Moonlight itself carries 2 shared — we follow the assignment literally and
note the delta here)."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=0, vocab=163840,
    moe_experts=64, moe_top_k=6, moe_shared=0, moe_d_ff=1408,
    rope_theta=50_000.0,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="moonshot-v1-16b-a3b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, vocab=256, moe_experts=8, moe_top_k=2,
        moe_d_ff=32, max_seq=128)
