"""Assigned input shapes and the per-(arch, shape) applicability rules."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

#: families with sub-quadratic attention that can serve a 500k-token decode
SUBQUADRATIC = ("ssm", "hybrid")


def applicable(family: str, shape: str) -> Tuple[bool, str]:
    """Does (arch family, shape) form a runnable cell?  Returns (ok, why)."""
    if shape == "long_500k" and family not in SUBQUADRATIC:
        return False, ("pure full-attention arch: 512k dense-attention decode "
                       "is quadratic with no sub-quadratic variant specified "
                       "(skip noted in DESIGN.md §Arch-applicability)")
    return True, ""
