"""llama-3.2-vision-11b: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — gated cross-attn image layers every 5th layer (8 total);
vision tower is a STUB: input_specs() supplies patch embeddings
[hf:meta-llama/Llama-3.2-11B-Vision]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256,
    cross_attn_interval=5, n_img_tokens=1601, rope_theta=500_000.0,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="llama-3.2-vision-11b-reduced", n_layers=10, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256, cross_attn_interval=5,
        n_img_tokens=16, max_seq=128)
