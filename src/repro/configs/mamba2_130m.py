"""mamba2-130m: 24L d_model=768 attn-free, ssm_state=128 — SSD
[arXiv:2405.21060]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768, n_heads=24,
    d_ff=0, vocab=50280, ssm_state=128, ssm_d_head=64, ssm_expand=2,
    conv_width=4,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="mamba2-130m-reduced", n_layers=2, d_model=64,
        n_heads=2, vocab=256, ssm_state=16, ssm_d_head=32, max_seq=128)
