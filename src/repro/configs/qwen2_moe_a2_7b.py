"""qwen2-moe-a2.7b: 24L d_model=2048 16H (kv=16) d_ff(expert)=1408
vocab=151936, MoE 60 experts top-4 + 4 shared
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=0, vocab=151936, qkv_bias=True,
    moe_experts=60, moe_top_k=4, moe_shared=4, moe_d_ff=1408,
    rope_theta=1_000_000.0,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2-moe-a2.7b-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, vocab=256, moe_experts=8, moe_top_k=2,
        moe_shared=2, moe_d_ff=32, max_seq=128)
