"""Fault-tolerant training loop.

- checkpoint/restart (atomic, includes the data cursor — restart-exact)
- elastic re-meshing (restore re-shards onto whatever devices exist)
- straggler watchdog (flags steps slower than ``straggler_factor`` x the
  running median — on real fleets this feeds the controller's replace list)
- NaN/divergence guard (skips the update and re-tries from last checkpoint
  after ``max_bad_steps``)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.models import api
from repro.models.config import ModelConfig
from repro.parallel.sharding import tree_shardings
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, DataSource, DataState
from repro.train.optim import OptConfig, init_opt
from repro.train.step import make_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_dir: str = "checkpoints"
    ckpt_every: int = 50
    log_every: int = 10
    resume: bool = True
    straggler_factor: float = 3.0
    max_bad_steps: int = 3
    seed: int = 0


class Trainer:
    def __init__(self, model_cfg: ModelConfig, opt_cfg: OptConfig,
                 data_cfg: DataConfig, loop_cfg: LoopConfig, mesh=None):
        self.mcfg, self.ocfg, self.dcfg, self.lcfg = (
            model_cfg, opt_cfg, data_cfg, loop_cfg)
        self.mesh = mesh
        self.data = DataSource(data_cfg, model_cfg)
        self.cfg_hash = ckpt.config_hash((model_cfg, opt_cfg, data_cfg))

        a_params = api.abstract_params(model_cfg)
        self.s_params = (tree_shardings(api.param_pspecs(model_cfg), mesh,
                                        a_params) if mesh else None)
        step_fn = make_train_step(model_cfg, opt_cfg, mesh=mesh)
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            repl = NamedSharding(mesh, P())
            from repro.train.optim import OptState
            s_opt = OptState(mu=self.s_params, nu=self.s_params, step=repl)
            self._step = jax.jit(step_fn,
                                 in_shardings=(self.s_params, s_opt, None),
                                 donate_argnums=(0, 1))
        else:
            self._step = jax.jit(step_fn, donate_argnums=(0, 1))

        self.params = None
        self.opt_state = None
        self.data_state = DataState(0)
        self.metrics_log = []
        self.step_times = []

    # -- state ----------------------------------------------------------------
    def init_or_restore(self) -> int:
        latest = ckpt.latest(self.lcfg.ckpt_dir) if self.lcfg.resume else None
        params = api.init_params(self.mcfg, jax.random.key(self.lcfg.seed))
        opt_state = init_opt(self.ocfg, params)
        if self.mesh is not None:
            params = jax.device_put(params, self.s_params)
        if latest is not None:
            tree = {"params": params, "opt": opt_state}
            sh = None
            if self.mesh is not None:
                from repro.train.optim import OptState
                from jax.sharding import NamedSharding, PartitionSpec as P
                repl = NamedSharding(self.mesh, P())
                sh = {"params": self.s_params,
                      "opt": OptState(mu=self.s_params, nu=self.s_params,
                                      step=repl)}
            tree, manifest = ckpt.restore(latest, tree, sh)
            if manifest["cfg_hash"] not in ("", self.cfg_hash):
                raise ValueError("checkpoint/config mismatch: "
                                 f"{manifest['cfg_hash']} != {self.cfg_hash}")
            self.params, self.opt_state = tree["params"], tree["opt"]
            self.data_state = DataState.from_dict(
                manifest.get("data_state", {"step": manifest["step"]}))
            return int(manifest["step"])
        self.params, self.opt_state = params, opt_state
        return 0

    def save(self, step: int) -> None:
        ckpt.save(self.lcfg.ckpt_dir, step,
                  {"params": self.params, "opt": self.opt_state},
                  data_state=self.data_state.to_dict(),
                  cfg_hash=self.cfg_hash)

    # -- loop -----------------------------------------------------------------
    def run(self, on_metrics: Optional[Callable[[Dict], None]] = None) -> Dict:
        start = self.init_or_restore()
        bad = 0
        for step in range(start, self.lcfg.steps):
            batch = self.data.batch_at(self.data_state)
            t0 = time.time()
            new_params, new_opt, metrics = self._step(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            self.step_times.append(dt)

            if not np.isfinite(loss):
                bad += 1
                if bad > self.lcfg.max_bad_steps:
                    raise RuntimeError(f"diverged at step {step}")
                # skip the poisoned update; keep old state (params were
                # donated — restore from checkpoint if buffers are gone)
                print(f"[train] step {step}: non-finite loss, skipping")
                start_ckpt = ckpt.latest(self.lcfg.ckpt_dir)
                if start_ckpt is not None:
                    self.init_or_restore()
                continue
            bad = 0
            self.params, self.opt_state = new_params, new_opt
            self.data_state.step += 1

            med = float(np.median(self.step_times[-20:]))
            if len(self.step_times) > 5 and dt > self.lcfg.straggler_factor * med:
                print(f"[train] step {step}: straggler ({dt:.2f}s vs "
                      f"median {med:.2f}s) — would flag host for replacement")

            m = {"step": step, "loss": loss,
                 "grad_norm": float(metrics["grad_norm"]), "time_s": dt}
            self.metrics_log.append(m)
            if on_metrics:
                on_metrics(m)
            if step % self.lcfg.log_every == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"gnorm {m['grad_norm']:.3f} {dt:.2f}s")
            if (step + 1) % self.lcfg.ckpt_every == 0:
                self.save(step + 1)
        self.save(self.lcfg.steps)
        return {"final_loss": self.metrics_log[-1]["loss"]
                if self.metrics_log else float("nan"),
                "steps": len(self.metrics_log)}
