"""Jittable train / serve steps with explicit output shardings."""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.config import ModelConfig
from repro.train.optim import OptConfig, OptState, apply_updates


def make_train_step(cfg: ModelConfig, opt: OptConfig, mesh=None):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state: OptState, batch: Dict[str, Any]):
        def loss_of(p):
            if cfg.cast_params_bf16:
                # one cast per step: FSDP weight all-gathers and the grad
                # reduce-scatters at this boundary move bf16, halving the
                # collective volume (optimizer math stays fp32)
                p = jax.tree.map(
                    lambda x: x.astype(jnp.bfloat16)
                    if x.dtype == jnp.float32 else x, p)
            return api.loss_fn(cfg, p, batch, mesh=mesh)

        loss, grads = jax.value_and_grad(loss_of)(params)
        params, opt_state, gnorm = apply_updates(opt, params, grads, opt_state)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt_state.step}
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg: ModelConfig):
    """(params, cache, tokens (B,1)) -> (logits (B,V), cache)."""

    def serve_step(params, cache, tokens):
        return api.decode_step(cfg, params, cache, tokens)

    return serve_step


def make_prefill_step(cfg: ModelConfig, mesh=None):
    """Inference-prefill: full forward, no cache, returns last-token logits."""

    def prefill_step(params, batch):
        logits, _ = api.forward_logits(cfg, params, batch, mesh=mesh)
        return logits[:, -1]

    return prefill_step
