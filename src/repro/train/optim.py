"""AdamW with bf16-or-fp32 moments, cosine schedule, global-norm clip."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jnp.ndarray


def init_opt(cfg: OptConfig, params) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return OptState(jax.tree.map(z, params), jax.tree.map(z, params),
                    jnp.zeros((), jnp.int32))


def schedule(cfg: OptConfig, step) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup)
                    / jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def apply_updates(cfg: OptConfig, params, grads, st: OptState
                  ) -> Tuple[Any, OptState, jnp.ndarray]:
    step = st.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, st.mu, st.nu)
    newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    newm = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    newv = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return newp, OptState(newm, newv, step), gn
