"""Checkpointing: atomic, sharded-aware, restart-exact.

Layout: ``<dir>/step_<n>/arrays.npz`` + ``manifest.json`` holding step,
data cursor, config hash and the flattened tree structure.  Writes go to a
temp dir and are renamed (preemption-safe); ``latest()`` picks the newest
complete checkpoint.  On restore, arrays are device_put against the *new*
mesh's shardings — elastic re-meshing: a checkpoint taken on one topology
restores onto another.
"""
from __future__ import annotations

import hashlib
import json
import shutil
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

SEP = "|"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = SEP.join(str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def config_hash(obj: Any) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


def save(ckpt_dir: str, step: int, tree: Any, *, data_state: Dict = None,
         cfg_hash: str = "", keep: int = 3) -> Path:
    root = Path(ckpt_dir)
    root.mkdir(parents=True, exist_ok=True)
    tmp = root / f".tmp_step_{step}_{int(time.time())}"
    tmp.mkdir()
    arrays = _flatten(tree)
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {"step": step, "data_state": data_state or {},
                "cfg_hash": cfg_hash, "time": time.time(),
                "n_arrays": len(arrays)}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    final = root / f"step_{step:08d}"
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic publish
    _gc(root, keep)
    return final


def _gc(root: Path, keep: int) -> None:
    ckpts = sorted(root.glob("step_*"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old, ignore_errors=True)


def latest(ckpt_dir: str) -> Optional[Path]:
    root = Path(ckpt_dir)
    if not root.exists():
        return None
    ckpts = sorted(p for p in root.glob("step_*")
                   if (p / "manifest.json").exists())
    return ckpts[-1] if ckpts else None


def restore(path: Path, tree_like: Any, shardings: Any = None
            ) -> Tuple[Any, Dict]:
    """Restore into the structure of ``tree_like``; device_put against
    ``shardings`` (tree of NamedSharding) when given — elastic re-mesh."""
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}
    paths = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    treedef = jax.tree_util.tree_structure(tree_like)
    leaves = []
    for p, old_leaf in paths:
        key = SEP.join(str(x) for x in p)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        a = arrays[key].astype(np.asarray(old_leaf).dtype
                               if hasattr(old_leaf, "dtype") else None)
        leaves.append(a)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, manifest
