"""Data pipeline: deterministic, resumable, shardable.

Production shape: a seeded token-stream source with an explicit cursor that
is checkpointed with the model (restart-exact).  Sources: synthetic LM
stream (zipf-mixture, default), or a binary token file memory-mapped and
chunked.  Batches come out host-sharded along the batch axis.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DataConfig:
    kind: str = "synthetic"       # "synthetic" | "tokens"
    path: Optional[str] = None    # for kind="tokens": int32 binary file
    seed: int = 0
    batch: int = 8
    seq: int = 512


class DataState:
    """Explicit cursor: (epoch, step) — serialized into checkpoints."""

    def __init__(self, step: int = 0):
        self.step = step

    def to_dict(self):
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(int(d["step"]))


class DataSource:
    def __init__(self, cfg: DataConfig, model: ModelConfig):
        self.cfg = cfg
        self.model = model
        if cfg.kind == "tokens":
            assert cfg.path, "kind='tokens' needs a path"
            self._tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")
        else:
            self._tokens = None

    def batch_at(self, state: DataState) -> Dict[str, np.ndarray]:
        """Deterministic batch for a given cursor (restart-exact)."""
        c, m = self.cfg, self.model
        if self._tokens is not None:
            n = c.batch * (c.seq + 1)
            total = len(self._tokens) - n - 1
            off = (state.step * n) % max(total, 1)
            flat = np.asarray(self._tokens[off:off + n]).reshape(
                c.batch, c.seq + 1)
        else:
            g = np.random.default_rng(
                np.random.PCG64(c.seed * 1_000_003 + state.step))
            # zipf-mixture synthetic stream: hot tokens + uniform tail
            hot = g.zipf(1.5, size=(c.batch, c.seq + 1)) % max(m.vocab // 8, 2)
            uni = g.integers(0, m.vocab, (c.batch, c.seq + 1))
            pick = g.random((c.batch, c.seq + 1)) < 0.7
            flat = np.where(pick, hot, uni).astype(np.int32)
        batch = {"tokens": flat[:, :-1].astype(np.int32),
                 "labels": flat[:, 1:].astype(np.int32)}
        if m.family == "vlm":
            g2 = np.random.default_rng(state.step + 17)
            batch["img"] = g2.standard_normal(
                (c.batch, m.n_img_tokens, m.d_model)).astype(np.float32)
        if m.family == "audio":
            g2 = np.random.default_rng(state.step + 23)
            batch["frames"] = g2.standard_normal(
                (c.batch, m.n_audio_frames, m.d_model)).astype(np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        st = DataState(0)
        while True:
            yield self.batch_at(st)
            st.step += 1
