"""Mamba-2 SSD (state-space duality, arXiv:2405.21060) — TPU-adapted.

The chunked SSD form is used for training/prefill: intra-chunk terms are
dense matmuls (MXU-friendly) and the inter-chunk recurrence is a short
``lax.scan`` over chunk states — this is the hardware adaptation of the
paper's warp-level scan (DESIGN.md §2 applies to the NoC simulator; the
same HBM->VMEM blocking logic applies here).  Decode is the O(1) recurrent
update.  Single SSM group (B/C shared across heads), like mamba2-130m.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import rmsnorm
from .config import ModelConfig

CHUNK = 128


def segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., Q) -> (..., Q, Q) lower-tri segment sums: out[i,j] = sum_{j<m<=i} x[m]."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
                b: jnp.ndarray, c: jnp.ndarray,
                h0: Optional[jnp.ndarray] = None):
    """SSD scan.

    x:  (B, S, H, P)  inputs per head
    dt: (B, S, H)     softplus'd step sizes
    a_log: (H,)       -exp(a_log) is the decay rate
    b, c: (B, S, N)   shared-input/output projections (single group)
    h0: (B, H, P, N)  optional initial state.
    Returns (y (B, S, H, P), h_final (B, H, P, N)).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(CHUNK, s)
    assert s % q == 0, (s, q)
    nc = s // q
    f32 = jnp.float32

    a = -jnp.exp(a_log.astype(f32))                      # (H,) negative
    dta = dt.astype(f32) * a[None, None, :]              # (B, S, H)
    xdt = x.astype(f32) * dt.astype(f32)[..., None]      # (B, S, H, P)

    # reshape into chunks
    dta_c = dta.reshape(bsz, nc, q, h)
    x_c = xdt.reshape(bsz, nc, q, h, p)
    b_c = b.astype(f32).reshape(bsz, nc, q, n)
    c_c = c.astype(f32).reshape(bsz, nc, q, n)

    # intra-chunk (diagonal) term: attention-like with decay kernel
    # (the exp(segsum) factor is 0 above the diagonal -> causal by mask)
    l = jnp.exp(segsum(dta_c.transpose(0, 1, 3, 2)))     # (B, nc, H, Q, Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", c_c, b_c)     # (B, nc, Q, Q)
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", scores, l, x_c)

    # chunk-final states: sum_k decay(end..k) * B_k x_k
    dta_cs = jnp.cumsum(dta_c, axis=2)                   # (B, nc, Q, H)
    decay_to_end = jnp.exp(dta_cs[:, :, -1:, :] - dta_cs)  # (B, nc, Q, H)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", b_c, decay_to_end, x_c)

    # inter-chunk recurrence over chunk index
    chunk_decay = jnp.exp(dta_cs[:, :, -1, :])           # (B, nc, H)
    h_init = jnp.zeros((bsz, h, p, n), f32) if h0 is None else h0.astype(f32)

    def step(carry, inp):
        st, cd = inp                                     # (B,H,P,N), (B,H)
        new = carry * cd[:, :, None, None] + st
        return new, carry                                # emit PRE-state

    h_fin, h_prevs = jax.lax.scan(
        step, h_init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)           # (B, nc, H, P, N)

    # off-diagonal: contribution of carried state into each position
    decay_from_start = jnp.exp(dta_cs)                   # (B, nc, Q, H)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", c_c, h_prevs,
                       decay_from_start)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y.astype(x.dtype), h_fin


def ssd_decode(x: jnp.ndarray, dt: jnp.ndarray, a_log: jnp.ndarray,
               b: jnp.ndarray, c: jnp.ndarray, h: jnp.ndarray):
    """One-token recurrent update.  x: (B, 1, H, P); b/c: (B, 1, N);
    h: (B, H, P, N) -> (y (B, 1, H, P), h')."""
    f32 = jnp.float32
    a = -jnp.exp(a_log.astype(f32))
    dta = dt[:, 0].astype(f32) * a[None, :]              # (B, H)
    decay = jnp.exp(dta)[:, :, None, None]
    xdt = (x[:, 0].astype(f32) * dt[:, 0].astype(f32)[..., None])  # (B,H,P)
    h_new = h.astype(f32) * decay + jnp.einsum(
        "bhp,bn->bhpn", xdt, b[:, 0].astype(f32))
    y = jnp.einsum("bhpn,bn->bhp", h_new, c[:, 0].astype(f32))
    return y[:, None].astype(x.dtype), h_new


def causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray,
                state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d.  xbc: (B, S, D); w: (K, D); state (B, K-1, D).
    Returns (y (B, S, D), new_state (B, K-1, D))."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)           # (B, S+K-1, D)
    y = sum(full[:, i:i + xbc.shape[1]] * w[i][None, None, :]
            for i in range(k))
    new_state = full[:, -(k - 1):] if k > 1 else pad
    return jax.nn.silu((y + bias[None, None]).astype(jnp.float32)
                       ).astype(xbc.dtype), new_state


def mamba2_mix(cfg: ModelConfig, p: dict, x: jnp.ndarray,
               cache: Optional[Tuple] = None, gated: bool = True):
    """Full mamba2 mixer.  x: (B, S, d_model) -> (y, new_cache).

    cache = (conv_state (B, K-1, conv_dim), ssm_state (B, H, P, N)).
    Param dict p: in_z (d, din) [optional], in_x (d, din), in_b (d, N),
    in_c (d, N), in_dt (d, H), conv_w (K, din+2N), conv_b, a_log (H,),
    d_skip (H,), dt_bias (H,), out (din, d).
    """
    bsz, s, _ = x.shape
    din = cfg.d_inner
    nh, ph, ns = cfg.n_ssm_heads, cfg.ssm_d_head, cfg.ssm_state

    xi = jnp.einsum("bsd,de->bse", x, p["in_x"].astype(x.dtype))
    bb = jnp.einsum("bsd,dn->bsn", x, p["in_b"].astype(x.dtype))
    cc = jnp.einsum("bsd,dn->bsn", x, p["in_c"].astype(x.dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, p["in_dt"].astype(x.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    xbc = jnp.concatenate([xi, bb, cc], axis=-1)
    conv_state = None if cache is None else cache[0]
    xbc, conv_state_new = causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xi = xbc[..., :din]
    bb = xbc[..., din:din + ns]
    cc = xbc[..., din + ns:]

    xh = xi.reshape(bsz, s, nh, ph)
    if cache is None:
        y, h_fin = ssd_chunked(xh, dt, p["a_log"], bb, cc)
        new_cache = None
    else:
        y, h_fin = ssd_decode(xh, dt, p["a_log"], bb, cc, cache[1])
        new_cache = (conv_state_new, h_fin)
    y = y + xh.astype(jnp.float32).astype(y.dtype) * \
        p["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, din)
    if gated and "in_z" in p:
        z = jnp.einsum("bsd,de->bse", x, p["in_z"].astype(x.dtype))
        y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["out"].astype(y.dtype)), new_cache


def mamba_block(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                cache: Optional[Tuple] = None):
    y = rmsnorm(x, p["ln"], cfg.norm_eps)
    o, new_cache = mamba2_mix(cfg, p, y, cache)
    return x + o, new_cache
