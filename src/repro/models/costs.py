"""Analytic FLOP/byte model (exact, auditable — the roofline compute term).

Why analytic: XLA's ``cost_analysis`` counts a while-loop body ONCE, so any
``lax.scan`` (layer stacks, flash-attention chunk loops) is undercounted by
its trip count.  Verified empirically: hymba (unrolled layers) reports sane
HLO FLOPs while scanned archs under-report by ~n_layers.  Bytes and
collectives are probe-corrected in the dry-run (see launch/dryrun.py);
FLOPs come from here, and flash-attention HBM traffic is topped up with
``attn_hbm_bytes``.
"""
from __future__ import annotations

from .config import ModelConfig

BYTES = 2  # bf16 activations/weights on the wire


def _attn_flops(cfg: ModelConfig, b: int, s: int, t: int,
                causal: bool) -> float:
    """Score+value flops for one attention layer (projections excluded)."""
    h, hd = cfg.n_heads, cfg.head_dim
    pairs = b * s * t * (0.5 if causal and s == t else 1.0)
    return 2.0 * pairs * h * hd * 2       # qk^T and pv

def _proj_flops(cfg: ModelConfig, b: int, s: int) -> float:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    return 2.0 * b * s * (d * h * hd + 2 * d * kv * hd + h * hd * d)


def _mlp_flops(cfg: ModelConfig, b: int, s: int) -> float:
    return 2.0 * b * s * 3 * cfg.d_model * cfg.d_ff


def _moe_flops(cfg: ModelConfig, b: int, s: int) -> float:
    act = 2.0 * b * s * 3 * cfg.d_model * cfg.moe_d_ff * cfg.moe_top_k \
        * cfg.capacity_factor
    shared = 2.0 * b * s * 3 * cfg.d_model * cfg.moe_d_ff * cfg.moe_shared
    router = 2.0 * b * s * cfg.d_model * cfg.moe_experts
    return act + shared + router


def _ssm_flops(cfg: ModelConfig, b: int, s: int) -> float:
    d, din, ns, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    proj = 2.0 * b * s * (d * (2 * din + 2 * ns + nh) + din * d)
    q = min(128, s)
    ssd = 2.0 * b * s * (q * ns + q * nh * cfg.ssm_d_head
                         + 2 * ns * nh * cfg.ssm_d_head)
    conv = 2.0 * b * s * cfg.conv_width * (din + 2 * ns)
    return proj + ssd + conv


def fwd_flops(cfg: ModelConfig, b: int, s: int, t: int | None = None) -> float:
    """Forward flops for s new tokens attending to t total positions."""
    t = t if t is not None else s
    causal = s == t
    f = 2.0 * b * s * cfg.d_model * cfg.vocab          # unembed
    f += 2.0 * b * s * cfg.d_model                      # embed gather ~free
    L = cfg.n_layers
    fam = cfg.family
    if fam in ("dense", "vlm"):
        f += L * (_proj_flops(cfg, b, s) + _attn_flops(cfg, b, s, t, causal)
                  + _mlp_flops(cfg, b, s))
        if fam == "vlm":
            g = L // cfg.cross_attn_interval
            f += g * (_proj_flops(cfg, b, s)
                      + _attn_flops(cfg, b, s, cfg.n_img_tokens, False)
                      + _mlp_flops(cfg, b, s))
    elif fam == "moe":
        f += L * (_proj_flops(cfg, b, s) + _attn_flops(cfg, b, s, t, causal)
                  + _moe_flops(cfg, b, s))
    elif fam == "ssm":
        f += L * _ssm_flops(cfg, b, s)
    elif fam == "hybrid":
        for l in range(L):
            if l in cfg.global_layers:
                tt = t
            elif getattr(cfg, "banded_attention", False):
                # banded sliding window: only the band is visited
                tt = min(cfg.window + 256, t)
            else:
                # baseline blocked attention scans the full key range and
                # masks outside the window (quadratic)
                tt = t
            f += (_proj_flops(cfg, b, s) + _attn_flops(cfg, b, s, tt, causal)
                  + _ssm_flops(cfg, b, s) + _mlp_flops(cfg, b, s))
    elif fam == "audio":
        se = cfg.n_audio_frames
        f += cfg.encoder_layers * (_proj_flops(cfg, b, se)
                                   + _attn_flops(cfg, b, se, se, False)
                                   + _mlp_flops(cfg, b, se))
        f += L * (_proj_flops(cfg, b, s) + _attn_flops(cfg, b, s, t, causal)
                  + _proj_flops(cfg, b, s)
                  + _attn_flops(cfg, b, s, se, False) + _mlp_flops(cfg, b, s))
    return f


def cell_flops(cfg: ModelConfig, kind: str, b: int, s: int) -> float:
    """Global analytic flops for one step of a (kind, batch, seq) cell."""
    if kind == "train":
        mult = 4.0 if cfg.remat else 3.0   # fwd + 2x bwd (+1 remat fwd)
        return mult * fwd_flops(cfg, b, s)
    if kind == "prefill":
        return fwd_flops(cfg, b, s)
    if kind == "decode":
        return fwd_flops(cfg, b, 1, t=s)
    raise ValueError(kind)


def attn_hbm_bytes(cfg: ModelConfig, kind: str, b: int, s: int) -> float:
    """Flash-attention HBM traffic not visible to the scanned-HLO probes:
    K/V re-read once per query chunk (q-chunk 512)."""
    if kind == "decode":
        return 0.0   # decode reads the cache once; probes capture it
    from .common import BLOCK_Q, BLOCK_THRESHOLD
    t = s
    if s * t <= BLOCK_THRESHOLD:
        return 0.0
    mult = 2.0 if kind == "train" else 1.0   # backward re-streams K/V
    kv_row = 2.0 * cfg.kv_heads * cfg.head_dim * BYTES

    def layer_bytes(t_eff, qc):
        nq = max(s // qc, 1)
        return nq * b * t_eff * kv_row

    if cfg.family == "hybrid":
        total = len(cfg.global_layers) * layer_bytes(t, BLOCK_Q)
        banded = getattr(cfg, "banded_attention", False)
        n_sw = cfg.n_layers - len(cfg.global_layers)
        t_sw = min(cfg.window + 256, t) if banded else t
        qc_sw = 256 if banded else BLOCK_Q
        total += n_sw * layer_bytes(t_sw, qc_sw)
        return mult * total
    return mult * cfg.n_layers * layer_bytes(t, BLOCK_Q)
