"""Mixture-of-Experts layer: top-k routing with capacity-based sort dispatch.

Expert-parallel friendly: expert weights carry an E-leading axis (sharded
over the ``model`` mesh axis); dispatch gathers tokens into (E, C, d) slots
via argsort so compiled FLOPs stay ~T·k·capacity·d·ff (no dense all-expert
matmul), which keeps the roofline's useful-compute ratio honest.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import rmsnorm, shard_act
from .config import ModelConfig


def top_k_routing(router_logits: jnp.ndarray, k: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(T, E) -> weights (T, k) softmaxed over the top-k, ids (T, k)."""
    vals, ids = jax.lax.top_k(router_logits, k)
    w = jax.nn.softmax(vals.astype(jnp.float32), axis=-1)
    return w, ids


#: dispatch groups — aligned with the (pod, data) batch shards so every
#: sort/scatter stays local to a data shard (per-device capacity, real-EP
#: semantics); must divide the token count, so it shrinks for tiny batches.
MOE_GROUPS = 32


def _dispatch_groups(t: int) -> int:
    g = MOE_GROUPS
    while t % g:
        g //= 2
    return max(g, 1)


def _grouped_moe(cfg: ModelConfig, p: dict, xg: jnp.ndarray,
                 mesh=None) -> jnp.ndarray:
    """Grouped dispatch: xg (G, Tg, d) -> (G, Tg, d), group axis explicit.

    Gather-formulated: the only scatters are on int32 index arrays (tiny);
    token data moves through batched gathers + expert matmuls.  Explicit
    UNCONSTRAINED sharding anchors keep the group axis data-sharded (a
    data-tensor scatter here fell back to replicated buffers — measured
    10x memory blow-up)."""
    from jax.sharding import PartitionSpec as P
    U = P.UNCONSTRAINED
    g_, tg, d = xg.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    dpg = ("pod", "data")
    garange = jnp.arange(g_, dtype=jnp.int32)[:, None]

    logits = jnp.einsum("gtd,de->gte", xg, p["router"].astype(xg.dtype))
    w, ids = top_k_routing(logits, k)                      # (G, Tg, k)

    cap = max(int(cfg.capacity_factor * tg * k / e + 1), 4)
    flat_e = ids.reshape(g_, tg * k)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)[None], (g_, tg * k))
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    stok = jnp.take_along_axis(flat_tok, order, axis=-1)
    first = jax.vmap(
        lambda a: jnp.searchsorted(a, a, side="left"))(se)
    pos_in_e = jnp.arange(tg * k, dtype=jnp.int32)[None] - first
    keep_sorted = pos_in_e < cap
    slot_sorted = jnp.where(keep_sorted, se * cap + pos_in_e, e * cap)

    # int32 maps only (cheap scatters)
    slot = jnp.zeros((g_, tg * k), jnp.int32).at[garange, order].set(
        slot_sorted).reshape(g_, tg, k)
    inv_tok = jnp.full((g_, e * cap + 1), tg, jnp.int32).at[
        garange, slot_sorted].set(jnp.where(keep_sorted, stok, tg))

    # dispatch = batched gather from zero-padded tokens.  Anchor shardings:
    # expert-parallel (e over model) when the expert count divides, else TP
    # on the hidden dims; d stays model-sharded through combine either way.
    mode = getattr(cfg, "moe_mode", "auto")
    ep = mesh is not None and "model" in mesh.shape \
        and e % mesh.shape["model"] == 0 and mode != "ftp"
    if mode == "ep":
        ep = True
    espec = P(dpg, "model", U, U) if ep else P(dpg, U, U, "model")
    dspec = P(dpg, U, U, "model")
    xt_pad = jnp.concatenate([xg, jnp.zeros((g_, 1, d), xg.dtype)], axis=1)
    xe = jnp.take_along_axis(xt_pad, inv_tok[:, :-1, None], axis=1)
    xe = xe.reshape(g_, e, cap, d)
    xe = shard_act(xe, P(dpg, "model", U, U) if ep else P(dpg, U, U, U), mesh)

    gate = jnp.einsum("gecd,edf->gecf", xe, p["w1"].astype(xe.dtype))
    up = jnp.einsum("gecd,edf->gecf", xe, p["w3"].astype(xe.dtype))
    z = jax.nn.silu(gate.astype(jnp.float32)).astype(xe.dtype) * up
    z = shard_act(z, espec, mesh)
    ye = jnp.einsum("gecf,efd->gecd", z, p["w2"].astype(xe.dtype))
    ye = shard_act(ye, dspec, mesh)

    # combine = gather back (dropped copies hit the zero pad row)
    yf = jnp.concatenate([ye.reshape(g_, e * cap, d),
                          jnp.zeros((g_, 1, d), ye.dtype)], axis=1)
    contrib = jnp.take_along_axis(
        yf, slot.reshape(g_, tg * k)[:, :, None], axis=1)
    contrib = contrib.reshape(g_, tg, k, d)
    contrib = shard_act(contrib, dspec, mesh)
    return jnp.einsum("gtkd,gtk->gtd", contrib.astype(jnp.float32),
                      w.astype(jnp.float32))


def moe_ffn(cfg: ModelConfig, p: dict, x: jnp.ndarray,
            mesh=None) -> jnp.ndarray:
    """x: (B, S, d) -> (B, S, d).  p: router (d, E), w1/w3 (E, d, f),
    w2 (E, f, d) + optional shared expert (w1s/w3s/w2s)."""
    from jax.sharding import PartitionSpec as P
    b, s, d = x.shape
    t = b * s
    g = _dispatch_groups(t)
    # groups align with the (pod, data) batch shards: dispatch is local
    gspec = P(("pod", "data"), None, None)
    xg = shard_act(x.reshape(g, t // g, d), gspec, mesh)
    out = _grouped_moe(cfg, p, xg, mesh)
    out = shard_act(out, gspec, mesh).reshape(b, s, d)

    if cfg.moe_shared > 0:
        xt = x.reshape(t, d)
        gs = jnp.einsum("td,df->tf", xt, p["w1s"].astype(xt.dtype))
        us = jnp.einsum("td,df->tf", xt, p["w3s"].astype(xt.dtype))
        zs = jax.nn.silu(gs.astype(jnp.float32)).astype(xt.dtype) * us
        out = out + jnp.einsum("tf,fd->td", zs, p["w2s"].astype(xt.dtype)
                               ).astype(jnp.float32).reshape(b, s, d)
    return out.astype(x.dtype)


def moe_block(cfg: ModelConfig, p: dict, x: jnp.ndarray,
              mesh=None) -> jnp.ndarray:
    y = rmsnorm(x, p["ln"], cfg.norm_eps)
    return x + moe_ffn(cfg, p, y, mesh)
