"""The architecture zoo: one parameter-tree builder + forward per family.

Families: dense (llama/qwen GQA), moe (qwen2-moe / moonlight), ssm
(mamba2 SSD), hybrid (hymba: parallel attn+SSM heads, sliding window),
vlm (llama-3.2-vision: gated cross-attn every 5th layer), audio (whisper
enc-dec; conv/mel frontend stubbed as precomputed frame embeddings).

Everything is a pure function over nested dict params; layer stacks are
scanned (small HLO, fast dry-run compiles) except hybrid, whose per-layer
cache shapes differ (window vs global layers).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import (attention, attn_block, cdt, cross_attn_block, rmsnorm,
                     rope, shard_act, swiglu)
from .config import ModelConfig
from .moe import moe_block
from .params import Alt, Leaf
from .ssm import causal_conv, mamba2_mix, mamba_block, ssd_chunked, ssd_decode

# mesh axis aliases used in the PartitionSpecs below
DP = ("pod", "data")     # batch axis
TP = "model"             # tensor axis
FS = ("pod", "data")     # FSDP axis: params/grads/moments sharded over data
                         # (GSPMD all-gathers weights per layer, reduce-
                         # scatters grads — ZeRO-3 semantics)


# ---------------------------------------------------------------------------
# parameter trees
# ---------------------------------------------------------------------------

def _attn_tree(cfg: ModelConfig, leaf: Leaf, pre: str, ln_kv: bool = False,
               gate: bool = False, lead: tuple = ()) -> Dict[str, Any]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    sc = 0.02
    lp = tuple(None for _ in lead)
    # primary: Megatron head-sharding (+FSDP on d); fallback: input-dim
    # row-parallel (+FSDP on head_dim)
    qkv_spec = Alt(P(*lp, FS, TP, None), P(*lp, TP, None, FS),
                   P(*lp, FS, None, None), P(*lp, None, None, None))
    o_spec = Alt(P(*lp, TP, None, FS), P(*lp, FS, None, TP),
                 P(*lp, None, None, FS), P(*lp, None, None, None))
    t = {
        "ln": leaf(pre + ".ln", lead + (d,), P(*lp, None), 1.0),
        "wq": leaf(pre + ".wq", lead + (d, h, hd), qkv_spec, sc),
        "wk": leaf(pre + ".wk", lead + (d, kv, hd), qkv_spec, sc),
        "wv": leaf(pre + ".wv", lead + (d, kv, hd), qkv_spec, sc),
        "wo": leaf(pre + ".wo", lead + (h, hd, d), o_spec, sc),
    }
    if cfg.qkv_bias:
        b_spec = Alt(P(*lp, TP, None), P(*lp, None, None))
        t["bq"] = leaf(pre + ".bq", lead + (h, hd), b_spec, 0.0)
        t["bk"] = leaf(pre + ".bk", lead + (kv, hd), b_spec, 0.0)
        t["bv"] = leaf(pre + ".bv", lead + (kv, hd), b_spec, 0.0)
    if ln_kv:
        t["ln_kv"] = leaf(pre + ".ln_kv", lead + (d,), P(*lp, None), 1.0)
    if gate:
        t["gate"] = leaf(pre + ".gate", lead + (1,), P(*lp, None), 0.0)
    return t


def _mlp_tree(cfg: ModelConfig, leaf: Leaf, pre: str, lead: tuple = ()):
    d, f = cfg.d_model, cfg.d_ff
    lp = tuple(None for _ in lead)
    return {
        "ln": leaf(pre + ".ln", lead + (d,), P(*lp, None), 1.0),
        "w1": leaf(pre + ".w1", lead + (d, f), Alt(P(*lp, FS, TP),
                                                   P(*lp, None, TP)), 0.02),
        "w3": leaf(pre + ".w3", lead + (d, f), Alt(P(*lp, FS, TP),
                                                   P(*lp, None, TP)), 0.02),
        "w2": leaf(pre + ".w2", lead + (f, d), Alt(P(*lp, TP, FS),
                                                   P(*lp, TP, None)), 0.02),
    }


def _moe_tree(cfg: ModelConfig, leaf: Leaf, pre: str, lead: tuple = ()):
    d, e, f = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    lp = tuple(None for _ in lead)
    # primary: expert parallelism (+FSDP on d); fallback: TP inside each
    # expert's FFN (+FSDP on d)
    w13_spec = Alt(P(*lp, TP, FS, None), P(*lp, None, FS, TP),
                   P(*lp, None, None, TP), P(*lp, None, None, None))
    w2_spec = Alt(P(*lp, TP, None, FS), P(*lp, None, TP, FS),
                  P(*lp, None, TP, None), P(*lp, None, None, None))
    t = {
        "ln": leaf(pre + ".ln", lead + (d,), P(*lp, None), 1.0),
        "router": leaf(pre + ".router", lead + (d, e), P(*lp, None, None), 0.02),
        "w1": leaf(pre + ".w1", lead + (e, d, f), w13_spec, 0.02),
        "w3": leaf(pre + ".w3", lead + (e, d, f), w13_spec, 0.02),
        "w2": leaf(pre + ".w2", lead + (e, f, d), w2_spec, 0.02),
    }
    if cfg.moe_shared:
        fs = cfg.moe_shared * f
        t["w1s"] = leaf(pre + ".w1s", lead + (d, fs), Alt(
            P(*lp, FS, TP), P(*lp, None, TP)), 0.02)
        t["w3s"] = leaf(pre + ".w3s", lead + (d, fs), Alt(
            P(*lp, FS, TP), P(*lp, None, TP)), 0.02)
        t["w2s"] = leaf(pre + ".w2s", lead + (fs, d), Alt(
            P(*lp, TP, FS), P(*lp, TP, None)), 0.02)
    return t


def _mamba_tree(cfg: ModelConfig, leaf: Leaf, pre: str, lead: tuple = (),
                gated: bool = True):
    d, din, ns = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.n_ssm_heads
    k = cfg.conv_width
    convd = din + 2 * ns
    lp = tuple(None for _ in lead)
    t = {
        "ln": leaf(pre + ".ln", lead + (d,), P(*lp, None), 1.0),
        "in_x": leaf(pre + ".in_x", lead + (d, din), Alt(
            P(*lp, FS, TP), P(*lp, None, TP)), 0.02),
        "in_b": leaf(pre + ".in_b", lead + (d, ns), P(*lp, FS, None), 0.02),
        "in_c": leaf(pre + ".in_c", lead + (d, ns), P(*lp, FS, None), 0.02),
        "in_dt": leaf(pre + ".in_dt", lead + (d, nh), P(*lp, FS, None), 0.02),
        "conv_w": leaf(pre + ".conv_w", lead + (k, convd), P(*lp, None, TP), 0.1),
        "conv_b": leaf(pre + ".conv_b", lead + (convd,), P(*lp, TP), 0.0),
        "a_log": leaf(pre + ".a_log", lead + (nh,), P(*lp, None), 0.5),
        "d_skip": leaf(pre + ".d_skip", lead + (nh,), P(*lp, None), 1.0),
        "dt_bias": leaf(pre + ".dt_bias", lead + (nh,), P(*lp, None), 0.5),
        "out": leaf(pre + ".out", lead + (din, d), Alt(
            P(*lp, TP, FS), P(*lp, TP, None)), 0.02),
    }
    if gated:
        t["in_z"] = leaf(pre + ".in_z", lead + (d, din), Alt(
            P(*lp, FS, TP), P(*lp, None, TP)), 0.02)
    return t


def param_tree(cfg: ModelConfig, leaf: Leaf) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab
    t: Dict[str, Any] = {
        "embed": leaf("embed", (v, d), Alt(P(TP, FS), P(FS, TP),
                                           P(None, TP)), 0.02),
        "ln_f": leaf("ln_f", (d,), P(None), 1.0),
    }
    if not cfg.tie_embeddings:
        t["head"] = leaf("head", (d, v), Alt(P(FS, TP), P(TP, FS),
                                             P(TP, None)), 0.02)
    L = (cfg.n_layers,)

    if cfg.family in ("dense",):
        t["layers"] = {**{"attn": _attn_tree(cfg, leaf, "L.attn", lead=L)},
                       "mlp": _mlp_tree(cfg, leaf, "L.mlp", lead=L)}
    elif cfg.family == "moe":
        t["layers"] = {"attn": _attn_tree(cfg, leaf, "L.attn", lead=L),
                       "moe": _moe_tree(cfg, leaf, "L.moe", lead=L)}
    elif cfg.family == "ssm":
        t["layers"] = {"mamba": _mamba_tree(cfg, leaf, "L.mamba", lead=L)}
    elif cfg.family == "hybrid":
        t["layers"] = {
            "attn": _attn_tree(cfg, leaf, "L.attn", lead=L),
            "mamba": _mamba_tree(cfg, leaf, "L.mamba", lead=L, gated=False),
            "mix_a": leaf("L.mix_a", L + (d,), P(None, None), 1.0),
            "mix_s": leaf("L.mix_s", L + (d,), P(None, None), 1.0),
            "mlp": _mlp_tree(cfg, leaf, "L.mlp", lead=L),
        }
    elif cfg.family == "vlm":
        g = cfg.n_layers // cfg.cross_attn_interval
        t["layers"] = {"attn": _attn_tree(cfg, leaf, "L.attn",
                                          lead=(g, cfg.cross_attn_interval - 1)),
                       "mlp": _mlp_tree(cfg, leaf, "L.mlp",
                                        lead=(g, cfg.cross_attn_interval - 1))}
        t["xlayers"] = {"xattn": _attn_tree(cfg, leaf, "X.attn", ln_kv=True,
                                            gate=True, lead=(g,)),
                        "mlp": _mlp_tree(cfg, leaf, "X.mlp", lead=(g,)),
                        "gate_mlp": leaf("X.gate_mlp", (g, 1), P(None, None), 0.0)}
    elif cfg.family == "audio":
        eL = (cfg.encoder_layers,)
        t["enc_pos"] = leaf("enc_pos", (cfg.n_audio_frames, d),
                            P(None, FS), 0.02)
        t["enc_layers"] = {"attn": _attn_tree(cfg, leaf, "E.attn", lead=eL),
                           "mlp": _mlp_tree(cfg, leaf, "E.mlp", lead=eL)}
        t["enc_ln_f"] = leaf("enc_ln_f", (d,), P(None), 1.0)
        t["dec_pos"] = leaf("dec_pos", (cfg.max_seq, d), P(FS, None), 0.02)
        t["layers"] = {"attn": _attn_tree(cfg, leaf, "D.attn", lead=L),
                       "xattn": _attn_tree(cfg, leaf, "D.xattn", ln_kv=True,
                                           lead=L),
                       "mlp": _mlp_tree(cfg, leaf, "D.mlp", lead=L)}
    else:
        raise ValueError(cfg.family)
    return t


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _maybe_remat(cfg: ModelConfig, fn):
    return jax.checkpoint(fn) if cfg.remat else fn


def _scan(cfg: ModelConfig, body, x, xs):
    """lax.scan over stacked layer params, or an unrolled Python loop when
    ``cfg.scan_layers`` is False (dry-run cost probes: XLA's cost_analysis
    counts a while-loop body once, so probes unroll to get true per-layer
    costs)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, x, xs)
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        sl = jax.tree.map(lambda a: a[i], xs)
        x, y = body(x, sl)
        ys.append(y)
    if ys and ys[0] is not None and not (isinstance(ys[0], tuple) and not ys[0]):
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = ()
    return x, ys


def _embed(cfg: ModelConfig, params, tokens):
    x = params["embed"].astype(cdt(cfg))[tokens]
    return x


def _unembed(cfg: ModelConfig, params, x):
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))


def _dense_layer(cfg, pl_, x, pos, cache, window=0):
    x, cache = attn_block(cfg, pl_["attn"], x, pos, cache, window=window)
    x = swiglu(cfg, pl_["mlp"], x)
    return x, cache


def _moe_layer(cfg, pl_, x, pos, cache, mesh=None):
    x, cache = attn_block(cfg, pl_["attn"], x, pos, cache)
    x = moe_block(cfg, pl_["moe"], x, mesh)
    return x, cache


def _hybrid_layer(cfg, pl_, x, pos, cache, layer_idx, is_global):
    """Hymba: attention heads and SSM heads in parallel on the same input."""
    window = 0 if is_global else cfg.window
    y = rmsnorm(x, pl_["attn"]["ln"], cfg.norm_eps)
    # attention branch (shares pl_["attn"] projections; no inner residual)
    b, s, _ = x.shape
    res, attn_cache = attn_block(
        cfg, pl_["attn"], x, pos,
        None if cache is None else cache[0], window=window)
    o_attn = res - x
    # SSM branch on the same normalized input
    o_ssm, ssm_cache = mamba2_mix(cfg, pl_["mamba"], y,
                                  None if cache is None else cache[1],
                                  gated=False)
    mixed = 0.5 * (o_attn * pl_["mix_a"].astype(x.dtype)
                   + o_ssm * pl_["mix_s"].astype(x.dtype))
    x = x + mixed
    x = swiglu(cfg, pl_["mlp"], x)
    new_cache = None if cache is None else (attn_cache, ssm_cache)
    return x, new_cache


def forward(cfg: ModelConfig, params, batch: Dict[str, jnp.ndarray],
            cache: Optional[Dict] = None, mesh=None):
    """Returns (logits, new_cache).  Train/prefill when cache is None.

    ``mesh`` enables sequence-parallel activation constraints (SP) on the
    residual stream — remat-saved activations shrink by the TP degree."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    sp = P(DP, TP, None)
    sa = (lambda t: shard_act(t, sp, mesh)) if cache is None else (lambda t: t)
    x = sa(_embed(cfg, params, tokens))
    if cache is None:
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        idx = None
    else:
        idx = cache["idx"]
        pos = jnp.broadcast_to(idx[None, None], (b, s)).astype(jnp.int32) \
            + jnp.arange(s, dtype=jnp.int32)[None]
        pos = pos.reshape(b, s)

    fam = cfg.family
    if fam in ("dense", "moe", "ssm"):
        if cache is None:
            def body_nc(xx, pl_):
                if fam == "dense":
                    xx, _ = _dense_layer(cfg, pl_, xx, pos, None)
                elif fam == "moe":
                    xx, _ = _moe_layer(cfg, pl_, xx, pos, None, mesh)
                else:
                    xx, _ = mamba_block(cfg, pl_["mamba"], xx, None)
                return sa(xx), ()
            body_nc = _maybe_remat(cfg, body_nc)
            x, _ = _scan(cfg, body_nc, x, params["layers"])
            new_cache = None
        else:
            if fam == "ssm":
                c_xs = (cache["conv"], cache["h"])
            else:
                c_xs = (cache["k"], cache["v"])

            def body_c(xx, inp):
                pl_, c_l = inp
                if fam == "dense":
                    xx, c_out = _dense_layer(cfg, pl_, xx, pos,
                                             (c_l[0], c_l[1], idx))
                    return xx, (c_out[0], c_out[1])
                if fam == "moe":
                    xx, c_out = _moe_layer(cfg, pl_, xx, pos,
                                           (c_l[0], c_l[1], idx), mesh)
                    return xx, (c_out[0], c_out[1])
                xx, c_out = mamba_block(cfg, pl_["mamba"], xx, c_l)
                return xx, c_out

            x, c_new = _scan(cfg, body_c, x, (params["layers"], c_xs))
            if fam == "ssm":
                new_cache = {"conv": c_new[0], "h": c_new[1],
                             "idx": idx + s}
            else:
                new_cache = {"k": c_new[0], "v": c_new[1], "idx": idx + s}

    elif fam == "hybrid":
        L = cfg.n_layers
        new_layer_caches = []
        for l in range(L):
            pl_ = jax.tree.map(lambda a: a[l], params["layers"])
            is_global = l in cfg.global_layers
            c_l = None if cache is None else \
                (((cache["layers"][l][0], cache["layers"][l][1], idx),
                  (cache["layers"][l][2], cache["layers"][l][3])))
            if cfg.remat and cache is None:
                x, c_out = jax.checkpoint(
                    lambda xx, pp=pl_, gl=is_global, ll=l:
                    _hybrid_layer(cfg, pp, xx, pos, None, ll, gl))(x)
                x = sa(x)
            else:
                x, c_out = _hybrid_layer(cfg, pl_, x, pos, c_l, l, is_global)
            if cache is not None:
                (kc, vc, _), (conv_s, h_s) = c_out
                new_layer_caches.append((kc, vc, conv_s, h_s))
        new_cache = None if cache is None else \
            {"layers": tuple(new_layer_caches), "idx": idx + s}

    elif fam == "vlm":
        img = batch["img"] if cache is None else cache["img"]
        g = cfg.n_layers // cfg.cross_attn_interval
        k_inner = cfg.cross_attn_interval - 1

        def group(xx, inp):
            """One group = (interval-1) self layers with a gated cross-attn
            block (xattn + gated FFN, llama-3.2-vision style) before the
            last self layer."""
            pl_, px_, c_l = inp
            outs_kv = []
            for j in range(k_inner):
                pj = jax.tree.map(lambda a: a[j], pl_)
                cj = None if c_l is None else (c_l[0][j], c_l[1][j], idx)
                if j == k_inner - 1:   # cross-attn before the last self layer
                    xx = cross_attn_block(cfg, px_["xattn"], xx, img)
                    gate = jnp.tanh(px_["gate_mlp"].astype(jnp.float32)
                                    ).astype(xx.dtype)
                    xx = xx + gate * (swiglu(cfg, px_["mlp"], xx) - xx)
                xx, cj_out = _dense_layer(cfg, pj, xx, pos, cj)
                if c_l is not None:
                    outs_kv.append((cj_out[0], cj_out[1]))
            if c_l is None:
                return xx, ()
            ks = jnp.stack([o[0] for o in outs_kv])
            vs = jnp.stack([o[1] for o in outs_kv])
            return xx, (ks, vs)

        if cache is None:
            gb = _maybe_remat(
                cfg,
                lambda xx, inp: (sa(group(xx, (inp[0], inp[1], None))[0]), ()))
            x, _ = _scan(cfg, gb, x, (params["layers"], params["xlayers"]))
            new_cache = None
        else:
            def g_c(xx, inp):
                pl_, px_, c_l = inp
                return group(xx, (pl_, px_, c_l))
            x, kv_new = _scan(
                cfg, g_c, x, (params["layers"], params["xlayers"],
                              (cache["k"], cache["v"])))
            new_cache = {"k": kv_new[0], "v": kv_new[1], "img": img,
                         "idx": idx + s}

    elif fam == "audio":
        if cache is None:
            enc = _encode_audio(cfg, params, batch["frames"])
        else:
            enc = cache["enc"]
        x = x + params["dec_pos"].astype(x.dtype)[pos]

        def dbody(xx, inp):
            pl_, c_l = inp
            cj = None if c_l is None else (c_l[0], c_l[1], idx)
            xx, c_out = attn_block(cfg, pl_["attn"], xx, pos, cj,
                                   rope_on=False)
            xx = cross_attn_block(cfg, pl_["xattn"], xx, enc, gated=False)
            xx = swiglu(cfg, pl_["mlp"], xx)
            if c_l is None:
                return xx, ()
            return xx, (c_out[0], c_out[1])

        if cache is None:
            db = _maybe_remat(
                cfg, lambda xx, pl_: (sa(dbody(xx, (pl_, None))[0]), ()))
            x, _ = _scan(cfg, db, x, params["layers"])
            new_cache = None
        else:
            x, kv_new = _scan(cfg, dbody, x,
                              (params["layers"],
                               (cache["k"], cache["v"])))
            new_cache = {"k": kv_new[0], "v": kv_new[1], "enc": enc,
                         "idx": idx + s}
    else:
        raise ValueError(fam)

    logits = _unembed(cfg, params, x)
    return logits, new_cache


def _encode_audio(cfg: ModelConfig, params, frames):
    """Whisper encoder over precomputed (stub) frame embeddings."""
    b, t, _ = frames.shape
    x = frames.astype(cdt(cfg)) + params["enc_pos"].astype(cdt(cfg))[None, :t]
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def ebody(xx, pl_):
        xx, _ = attn_block(cfg, pl_["attn"], xx, pos, None, causal=False,
                           rope_on=False)
        xx = swiglu(cfg, pl_["mlp"], xx)
        return xx, ()

    eb = _maybe_remat(cfg, ebody)
    x, _ = _scan(cfg, eb, x, params["enc_layers"])
    return rmsnorm(x, params["enc_ln_f"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def cache_tree(cfg: ModelConfig, leaf, batch_size: int, cache_len: int):
    """Decode-cache pytree via the leaf callback (real zeros or abstract)."""
    L, kv, hd = cfg.n_layers, cfg.kv_heads, cfg.head_dim
    dt = cfg.compute_dtype
    fam = cfg.family
    mk = lambda name, shape, spec: leaf(name, shape, spec, 0.0)
    idx = leaf("idx", (), P(), 0.0)
    if fam in ("dense", "moe"):
        return {"k": mk("ck", (L, batch_size, cache_len, kv, hd),
                        P(None, DP, None, None, None)),
                "v": mk("cv", (L, batch_size, cache_len, kv, hd),
                        P(None, DP, None, None, None)),
                "idx": idx}
    if fam == "ssm":
        convd = cfg.d_inner + 2 * cfg.ssm_state
        return {"conv": mk("conv", (L, batch_size, cfg.conv_width - 1, convd),
                           P(None, DP, None, TP)),
                "h": mk("h", (L, batch_size, cfg.n_ssm_heads, cfg.ssm_d_head,
                              cfg.ssm_state), P(None, DP, TP, None, None)),
                "idx": idx}
    if fam == "hybrid":
        convd = cfg.d_inner + 2 * cfg.ssm_state
        layers = []
        for l in range(L):
            t = cache_len if l in cfg.global_layers else min(cfg.window,
                                                             cache_len)
            layers.append((
                mk(f"ck{l}", (batch_size, t, kv, hd), P(DP, None, None, None)),
                mk(f"cv{l}", (batch_size, t, kv, hd), P(DP, None, None, None)),
                mk(f"conv{l}", (batch_size, cfg.conv_width - 1, convd),
                   P(DP, None, TP)),
                mk(f"h{l}", (batch_size, cfg.n_ssm_heads, cfg.ssm_d_head,
                             cfg.ssm_state), P(DP, TP, None, None)),
            ))
        return {"layers": tuple(layers), "idx": idx}
    if fam == "vlm":
        g = cfg.n_layers // cfg.cross_attn_interval
        k_inner = cfg.cross_attn_interval - 1
        return {"k": mk("ck", (g, k_inner, batch_size, cache_len, kv, hd),
                        P(None, None, DP, None, None, None)),
                "v": mk("cv", (g, k_inner, batch_size, cache_len, kv, hd),
                        P(None, None, DP, None, None, None)),
                "img": mk("img", (batch_size, cfg.n_img_tokens, cfg.d_model),
                          P(DP, None, None)),
                "idx": idx}
    if fam == "audio":
        return {"k": mk("ck", (L, batch_size, cache_len, kv, hd),
                        P(None, DP, None, None, None)),
                "v": mk("cv", (L, batch_size, cache_len, kv, hd),
                        P(None, DP, None, None, None)),
                "enc": mk("enc", (batch_size, cfg.n_audio_frames, cfg.d_model),
                          P(DP, None, None)),
                "idx": idx}
    raise ValueError(fam)
