"""Parameter-tree construction with a single source of truth.

Every model family defines one ``tree(cfg, leaf)`` function where ``leaf``
is a callback ``leaf(name, shape, spec, scale)``.  Instantiating it with
different callbacks yields real parameters, ShapeDtypeStructs (dry-run) or
PartitionSpec trees — the three can never drift.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Leaf = Callable[[str, tuple, P, float], Any]


class Alt(tuple):
    """Ordered sharding alternatives; the resolver picks the first whose
    sharded dims divide evenly on the target mesh (e.g. GQA head-sharding
    falls back to input-dim row-parallel when heads % tp != 0)."""

    def __new__(cls, *specs: P):
        return super().__new__(cls, specs)


def init_leaf(rng: jax.Array, dtype) -> Leaf:
    """Initializer; folds the leaf name into the key.

    Conventions: ``scale == 0`` -> zeros (biases, gates);
    ``scale == 1`` -> ones (norm scales); otherwise normal * scale.
    """
    def leaf(name: str, shape: tuple, spec: P, scale: float):
        if scale == 0.0:
            return jnp.zeros(shape, dtype)
        if scale == 1.0:
            return jnp.ones(shape, dtype)
        key = jax.random.fold_in(rng, zlib_crc(name))
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    return leaf


def abstract_leaf(dtype) -> Leaf:
    def leaf(name, shape, spec, scale):
        return jax.ShapeDtypeStruct(shape, dtype)
    return leaf


def spec_leaf() -> Leaf:
    def leaf(name, shape, spec, scale):
        return spec
    return leaf


def zlib_crc(name: str) -> int:
    import zlib
    return zlib.crc32(name.encode()) & 0x7FFFFFFF
