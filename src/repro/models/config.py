"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | vlm | ssm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_kv_heads: int = 0         # 0 -> = n_heads
    d_head: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared: int = 0         # shared experts (each of width moe_d_ff)
    moe_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_d_head: int = 64
    conv_width: int = 4
    ssm_expand: int = 2         # d_inner = expand * d_model (mamba2)

    # hybrid (hymba): sliding-window attention + parallel SSM heads
    window: int = 0             # 0 -> full attention
    global_layers: Tuple[int, ...] = ()

    # VLM
    cross_attn_interval: int = 0    # 5 -> cross-attn at 5g+3 (llama-vision)
    n_img_tokens: int = 0

    # enc-dec (whisper; conv/mel frontend is a stub -> frame embeddings)
    encoder_layers: int = 0
    n_audio_frames: int = 0

    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    max_seq: int = 8192

    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    use_pallas_attention: bool = False
    scan_layers: bool = True
    banded_attention: bool = False   # O(S-window) sliding-window blocks
    cast_params_bf16: bool = False   # cast once per step: bf16 FSDP gathers
    moe_mode: str = "auto"           # auto | ep | ftp (expert sharding)

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        if self.family == "hybrid":
            return self.n_heads * self.head_dim
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        if self.ssm_heads:
            return self.ssm_heads
        return self.d_inner // self.ssm_d_head

    def n_params(self) -> int:
        """Total parameter count (used for 6·N·D roofline math)."""
        d, ff, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        h, kv, hd = self.n_heads, self.kv_heads, self.head_dim
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "vlm", "audio", "hybrid"):
            per_layer += attn + 3 * d * ff + 2 * d
        if self.family == "moe":
            per_layer += attn + 2 * d
            per_layer += self.moe_experts * 3 * d * self.moe_d_ff
            per_layer += self.moe_shared * 3 * d * self.moe_d_ff
            per_layer += d * self.moe_experts
        if self.family == "ssm":
            din, ns, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
            per_layer = (d * (2 * din + 2 * ns + nh) + din * d
                         + self.conv_width * (din + 2 * ns) + 2 * nh + d)
        if self.family == "hybrid":
            din, ns, nh = self.d_inner, self.ssm_state, self.n_ssm_heads
            per_layer += d * 2 * din + din * d + self.conv_width * (din + 2 * ns) \
                + d * 2 * ns + 2 * nh
        total = L * per_layer + emb + d
        if self.family == "vlm":
            k = self.n_layers // self.cross_attn_interval
            total += k * (attn + 2 * d)   # gated cross-attn blocks
        if self.family == "audio":
            total += self.encoder_layers * (attn + 3 * d * ff + 2 * d)
            total += self.n_audio_frames * d      # learned enc positions
        return int(total)

    def n_active_params(self) -> int:
        """Active parameters per token (MoE): 6·N_active·D."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        routed_all = self.n_layers * self.moe_experts * 3 * d * self.moe_d_ff
        routed_act = self.n_layers * self.moe_top_k * 3 * d * self.moe_d_ff
        return self.n_params() - routed_all + routed_act
