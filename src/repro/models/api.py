"""Public model API: parameters, shardings, inputs, loss, decode.

Everything needed by the trainer, server and dry-run:

  abstract_params / init_params / param_pspecs     (never drift: one tree fn)
  input_specs(cfg, shape)                          ShapeDtypeStruct stand-ins
  loss_fn(cfg, params, batch)                      causal-LM cross entropy
  decode_step(cfg, params, cache, tokens)          one-token serve step
  abstract_cache / init_cache / cache_pspecs
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .params import abstract_leaf, init_leaf, spec_leaf
from .zoo import DP, cache_tree, forward, param_tree


def abstract_params(cfg: ModelConfig):
    return param_tree(cfg, abstract_leaf(jnp.dtype(cfg.param_dtype)))


def init_params(cfg: ModelConfig, rng: jax.Array):
    return param_tree(cfg, init_leaf(rng, jnp.dtype(cfg.param_dtype)))


def param_pspecs(cfg: ModelConfig):
    return param_tree(cfg, spec_leaf())


def abstract_cache(cfg: ModelConfig, batch: int, cache_len: int):
    dt = jnp.dtype(cfg.compute_dtype)
    leaf = lambda name, shape, spec, sc: jax.ShapeDtypeStruct(
        shape, jnp.int32 if name == "idx" else dt)
    return cache_tree(cfg, leaf, batch, cache_len)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    dt = jnp.dtype(cfg.compute_dtype)
    leaf = lambda name, shape, spec, sc: jnp.zeros(
        shape, jnp.int32 if name == "idx" else dt)
    return cache_tree(cfg, leaf, batch, cache_len)


def cache_pspecs(cfg: ModelConfig, batch: int = 1, cache_len: int = 128):
    return cache_tree(cfg, lambda n, s, spec, sc: spec, batch, cache_len)


# ---------------------------------------------------------------------------
# inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, kind: str, global_batch: int,
                seq_len: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run safe)."""
    i32 = jnp.int32
    dt = jnp.dtype(cfg.compute_dtype)
    b = global_batch
    if kind == "train" or kind == "prefill":
        s = seq_len
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.family == "vlm":
            out["img"] = jax.ShapeDtypeStruct(
                (b, cfg.n_img_tokens, cfg.d_model), dt)
        if cfg.family == "audio":
            out["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.n_audio_frames, cfg.d_model), dt)
        return out
    if kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    raise ValueError(kind)


def input_pspecs(cfg: ModelConfig, kind: str) -> Dict[str, P]:
    out = {"tokens": P(DP, None)}
    if kind == "train":
        out["labels"] = P(DP, None)
    if kind in ("train", "prefill"):
        if cfg.family == "vlm":
            out["img"] = P(DP, None, None)
        if cfg.family == "audio":
            out["frames"] = P(DP, None, None)
    return out


def make_inputs(cfg: ModelConfig, kind: str, batch: int, seq: int,
                rng: np.random.Generator) -> Dict[str, jnp.ndarray]:
    """Concrete random inputs (smoke tests / examples)."""
    out = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)}
    if kind == "train":
        out["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)
    if kind in ("train", "prefill"):
        if cfg.family == "vlm":
            out["img"] = jnp.asarray(
                rng.standard_normal((batch, cfg.n_img_tokens, cfg.d_model)),
                jnp.dtype(cfg.compute_dtype))
        if cfg.family == "audio":
            out["frames"] = jnp.asarray(
                rng.standard_normal((batch, cfg.n_audio_frames, cfg.d_model)),
                jnp.dtype(cfg.compute_dtype))
    return out


# ---------------------------------------------------------------------------
# loss / decode
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params, batch, mesh=None) -> jnp.ndarray:
    logits, _ = forward(cfg, params, batch, cache=None, mesh=mesh)
    labels = batch["labels"]
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    # gold logit via masked reduction (vocab stays sharded; a gather here
    # would all-gather the full logits)
    vidx = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(vidx == labels[..., None], lf, 0.0), axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def forward_logits(cfg: ModelConfig, params, batch, mesh=None):
    """Cache-free forward (training/prefill)."""
    return forward(cfg, params, batch, cache=None, mesh=mesh)


def decode_step(cfg: ModelConfig, params, cache, tokens):
    """One decode step: tokens (B, 1) -> (logits (B, vocab), new cache)."""
    logits, new_cache = forward(cfg, params, {"tokens": tokens}, cache=cache)
    return logits[:, -1], new_cache


def prefill(cfg: ModelConfig, params, batch, cache):
    """Run the prompt through the model writing into the cache."""
    logits, new_cache = forward(cfg, params, batch, cache=cache)
    return logits[:, -1], new_cache
