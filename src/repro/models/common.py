"""Shared transformer layers (pure functions over param dicts)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig


def cdt(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def shard_act(x: jnp.ndarray, spec, mesh) -> jnp.ndarray:
    """Megatron-style activation sharding constraint (no-op without a mesh).

    Used to keep the residual stream sequence-sharded between layers so
    remat-saved activations shrink by the tensor-parallel degree; GSPMD
    inserts the all-gather/reduce-scatter pair around attention/FFN."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding
    from repro.parallel.sharding import resolve_pspec
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve_pspec(spec, mesh, x.shape)))


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); pos: (B, S) or (S,)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if pos.ndim == 1:
        pos = pos[None, :]
    ang = pos[..., None].astype(jnp.float32) * freq          # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def _mask_logits(logits: jnp.ndarray, q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                 causal: bool, window: int) -> jnp.ndarray:
    """logits: (B, H, S, T); positions broadcastable (B, S)/(B, T)."""
    ok = jnp.ones(logits.shape[-2:], bool)[None, None]
    qp = q_pos[:, None, :, None]
    kp = k_pos[:, None, None, :]
    if causal:
        ok = ok & (qp >= kp)
    if window > 0:
        ok = ok & (qp - kp < window)
    return jnp.where(ok, logits, -1e30)


#: chunk sizes for the blocked (flash-style) XLA attention path
BLOCK_Q = 512
BLOCK_K = 1024
#: use blocked attention when S*T exceeds this (full scores would blow VMEM/HBM)
BLOCK_THRESHOLD = 2048 * 2048


def _blocked_attention(q, k, v, q_pos, k_pos, causal, window,
                       qc=BLOCK_Q, kc=BLOCK_K, banded: bool = False):
    """Online-softmax attention, chunked over queries and keys.

    Peak memory per step is (B, KV, rep, qc, kc) instead of (B, H, S, T) —
    the XLA analogue of the Pallas flash kernel (used on CPU/dry-run so the
    compiled HLO carries the true cost model).

    ``banded=True`` (sliding-window path): each query chunk visits only the
    k-chunks intersecting its [q-window, q] band — O(S·window) instead of
    O(S·T) compute/traffic.  Requires contiguous positions (train/prefill).
    """
    b, s, h, d = q.shape
    t = k.shape[1]
    kvh = k.shape[2]
    rep = h // kvh
    qc = min(qc, s)
    kc = min(kc, t)
    while s % qc:
        qc //= 2
    while t % kc:
        kc //= 2
    nq, nk = s // qc, t // kc
    f32 = jnp.float32
    qf = (q.astype(f32) / (d ** 0.5)).reshape(b, nq, qc, kvh, rep, d)
    qpos_c = q_pos.reshape(b, nq, qc)

    use_band = banded and window > 0 and causal
    # k-chunks per band: cover [qi*qc - window + 1 .. qi*qc + qc - 1]
    nk_band = min(nk, (window + qc - 2) // kc + 2) if use_band else nk

    def q_chunk(qi_):
        qcur, qp, qi = qi_
        m0 = jnp.full((b, kvh, rep, qc), -1e30, f32)
        l0 = jnp.zeros((b, kvh, rep, qc), f32)
        a0 = jnp.zeros((b, qc, kvh, rep, d), f32)
        if use_band:
            lo = jnp.maximum(qi * qc - (window - 1), 0) // kc
        else:
            lo = jnp.zeros((), jnp.int32)

        @jax.checkpoint
        def k_chunk(carry, j):
            m, l, acc = carry
            in_range = (lo + j) < nk     # banded tail: mask, never revisit
            kj = jnp.clip(lo + j, 0, nk - 1)
            ks = jax.lax.dynamic_slice_in_dim(k, kj * kc, kc, 1).astype(f32)
            vs = jax.lax.dynamic_slice_in_dim(v, kj * kc, kc, 1).astype(f32)
            kp = jax.lax.dynamic_slice_in_dim(k_pos, kj * kc, kc, 1)
            lg = jnp.einsum("bqkrd,btkd->bkrqt", qcur, ks)
            ok = jnp.broadcast_to(in_range, (b, 1, 1, qc, kc))
            qp_ = qp[:, None, None, :, None]
            kp_ = kp[:, None, None, None, :]
            if causal:
                ok = ok & (qp_ >= kp_)
            if window > 0:
                ok = ok & (qp_ - kp_ < window)
            lg = jnp.where(ok, lg, -1e30)
            m_new = jnp.maximum(m, jnp.max(lg, axis=-1))
            p = jnp.exp(lg - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + \
                jnp.einsum("bkrqt,btkd->bqkrd", p, vs)
            return (m_new, l_new, acc_new), ()

        (m, l, acc), _ = jax.lax.scan(k_chunk, (m0, l0, a0),
                                      jnp.arange(nk_band))
        denom = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return acc / denom                          # (b, qc, kvh, rep, d)

    out = jax.lax.map(jax.checkpoint(q_chunk),
                      (qf.transpose(1, 0, 2, 3, 4, 5),
                       qpos_c.transpose(1, 0, 2),
                       jnp.arange(nq, dtype=jnp.int32)))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, d)
    return out.astype(q.dtype)


def attention(cfg: ModelConfig, q: jnp.ndarray, k: jnp.ndarray,
              v: jnp.ndarray, q_pos: jnp.ndarray, k_pos: jnp.ndarray,
              causal: bool = True, window: int = 0) -> jnp.ndarray:
    """GQA attention.  q: (B, S, H, D); k/v: (B, T, KV, D) -> (B, S, H, D)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    if cfg.use_pallas_attention and window == 0 and q_pos.shape == k_pos.shape \
            and s % 128 == 0 and k.shape[1] % 128 == 0:
        from repro.kernels import ops as kops
        kk = jnp.repeat(k, rep, axis=2) if rep > 1 else k
        vv = jnp.repeat(v, rep, axis=2) if rep > 1 else v
        o = kops.attention(q.transpose(0, 2, 1, 3), kk.transpose(0, 2, 1, 3),
                           vv.transpose(0, 2, 1, 3), causal=causal,
                           backend="pallas")
        return o.transpose(0, 2, 1, 3)
    if s > 1 and s * k.shape[1] > BLOCK_THRESHOLD:
        banded = getattr(cfg, "banded_attention", False) and window > 0
        if banded:
            # window-matched chunks: visited pairs ~ S*(window+qc) instead
            # of S*T — small chunks tighten the band
            return _blocked_attention(q, k, v, q_pos, k_pos, causal, window,
                                      qc=256, kc=256, banded=True)
        return _blocked_attention(q, k, v, q_pos, k_pos, causal, window)
    qf = q.astype(jnp.float32) / (d ** 0.5)
    qg = qf.reshape(b, s, kvh, rep, d)
    logits = jnp.einsum("bskrd,btkd->bkrst", qg, k.astype(jnp.float32))
    logits = logits.reshape(b, kvh * rep, s, k.shape[1])
    logits = _mask_logits(logits, q_pos, k_pos, causal, window)
    w = jax.nn.softmax(logits, axis=-1)
    wg = w.reshape(b, kvh, rep, s, k.shape[1])
    o = jnp.einsum("bkrst,btkd->bskrd", wg, v.astype(jnp.float32))
    return o.reshape(b, s, h, d).astype(q.dtype)


def attn_block(cfg: ModelConfig, p: dict, x: jnp.ndarray, pos: jnp.ndarray,
               cache: Optional[Tuple] = None, causal: bool = True,
               window: int = 0, rope_on: bool = True):
    """Self-attention block (pre-norm, residual).  Returns (x, new_cache).

    cache = (k_cache (B, T, KV, D), v_cache, write_idx) for decode; the
    write index is a rolling pointer when ``window`` bounds the cache.
    """
    b, s, _ = x.shape
    h, kvh, d = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    y = rmsnorm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", y, p["wq"].astype(y.dtype))
    k = jnp.einsum("bsd,dhk->bshk", y, p["wk"].astype(y.dtype))
    v = jnp.einsum("bsd,dhk->bshk", y, p["wv"].astype(y.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(y.dtype)
        k = k + p["bk"].astype(y.dtype)
        v = v + p["bv"].astype(y.dtype)
    if rope_on:
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)

    if cache is None:
        o = attention(cfg, q, k, v, pos if pos.ndim == 2 else
                      jnp.broadcast_to(pos[None], (b, s)),
                      pos if pos.ndim == 2 else
                      jnp.broadcast_to(pos[None], (b, s)),
                      causal=causal, window=window)
        new_cache = None
    else:
        kc, vc, idx = cache
        t = kc.shape[1]
        slot = idx % t if window > 0 else idx
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype),
                                          (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype),
                                          (0, slot, 0, 0))
        # absolute positions of cache slots
        if window > 0:
            base = idx - slot
            kpos = jnp.arange(t)[None, :] + base
            kpos = jnp.where(jnp.arange(t)[None, :] <= slot, kpos, kpos - t)
        else:
            kpos = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))
        qpos = jnp.broadcast_to(pos[None] if pos.ndim == 1 else pos, (b, s))
        valid = (kpos >= 0) & (kpos <= idx)
        kpos_m = jnp.where(valid, kpos, 1 << 30)
        o = attention(cfg, q, kc, vc, qpos, kpos_m, causal=True,
                      window=window)
        new_cache = (kc, vc, idx + s)

    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    return x + o, new_cache


def swiglu(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = rmsnorm(x, p["ln"], cfg.norm_eps)
    g = jnp.einsum("bsd,df->bsf", y, p["w1"].astype(y.dtype))
    u = jnp.einsum("bsd,df->bsf", y, p["w3"].astype(y.dtype))
    z = jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype) * u
    return x + jnp.einsum("bsf,fd->bsd", z, p["w2"].astype(y.dtype))


def cross_attn_block(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                     kv_embed: jnp.ndarray, gated: bool = True):
    """Cross-attention onto precomputed embeddings (vision / audio)."""
    b, s, _ = x.shape
    t = kv_embed.shape[1]
    y = rmsnorm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", y, p["wq"].astype(y.dtype))
    kvn = rmsnorm(kv_embed, p["ln_kv"], cfg.norm_eps) if "ln_kv" in p else kv_embed
    k = jnp.einsum("btd,dhk->bthk", kvn.astype(y.dtype), p["wk"].astype(y.dtype))
    v = jnp.einsum("btd,dhk->bthk", kvn.astype(y.dtype), p["wv"].astype(y.dtype))
    qpos = jnp.zeros((b, s), jnp.int32)
    kpos = jnp.zeros((b, t), jnp.int32)
    o = attention(cfg, q, k, v, qpos, kpos, causal=False)
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))
    if gated:
        o = o * jnp.tanh(p["gate"].astype(jnp.float32)).astype(o.dtype)
    return x + o
