"""Dev harness: run every reduced arch through forward/loss/decode on CPU."""
import sys

sys.path.insert(0, "src")

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import api
from repro.models.config import ModelConfig


def check(arch_id: str) -> None:
    cfg = registry.reduced(arch_id)
    rng = np.random.default_rng(0)
    params = api.init_params(cfg, jax.random.key(0))
    nleaves = len(jax.tree.leaves(params))

    batch = api.make_inputs(cfg, "train", 2, 32, rng)
    loss = jax.jit(lambda p, b: api.loss_fn(cfg, p, b))(params, batch)
    assert jnp.isfinite(loss), (arch_id, loss)

    # grad step sanity
    g = jax.jit(jax.grad(lambda p: api.loss_fn(cfg, p, batch)))(params)
    gn = jax.tree.reduce(lambda a, b: a + b,
                         jax.tree.map(lambda x: jnp.sum(jnp.abs(x.astype(jnp.float32))), g))
    assert jnp.isfinite(gn) and gn > 0, (arch_id, gn)

    # decode step
    cache = api.init_cache(cfg, 2, 64)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (2, 1)), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, t: api.decode_step(cfg, p, c, t))(params, cache, tok)
    assert logits.shape == (2, cfg.vocab), (arch_id, logits.shape)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32))), arch_id
    # second step advances the index
    logits2, cache3 = jax.jit(
        lambda p, c, t: api.decode_step(cfg, p, c, t))(params, cache2, tok)
    assert int(cache3["idx"]) == 2, (arch_id, int(cache3["idx"]))

    full = registry.get(arch_id)
    print(f"OK {arch_id:24s} loss={float(loss):8.4f} leaves={nleaves:3d} "
          f"N={full.n_params()/1e9:6.2f}B active={full.n_active_params()/1e9:6.2f}B")


if __name__ == "__main__":
    ids = sys.argv[1:] or registry.ARCH_IDS
    for a in ids:
        try:
            check(a)
        except Exception as e:
            import traceback
            print(f"FAIL {a}: {type(e).__name__}: {e}")
            traceback.print_exc(limit=8)
