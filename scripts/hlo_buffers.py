"""Dev tool: print the largest tensors in a cell's optimized HLO."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re
import sys
from collections import Counter

sys.path.insert(0, "src")

SHAPE = re.compile(r"%?([\w\.\-]+) = ([a-z0-9]+)\[([0-9,]*)\]")
BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
         "u16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "f64": 8}


def main(arch, shape, mesh):
    from repro.launch.dryrun import run_cell
    import repro.launch.dryrun as dr
    # monkeypatch to capture hlo
    import repro.launch.dryrun as d

    # rebuild the cell manually to get compiled text
    from repro.configs import registry
    from repro.configs.shapes import SHAPES
    old = d.lm_cell

    captured = {}
    orig_collect = d.collective_bytes
    def spy(text):
        captured["hlo"] = text
        return orig_collect(text)
    d.collective_bytes = spy
    res = run_cell(arch, shape, mesh)
    text = captured.get("hlo", "")
    sizes = []
    for m in SHAPE.finditer(text):
        name, dt, dims = m.groups()
        if dt not in BYTES:
            continue
        n = 1
        for x in dims.split(","):
            if x:
                n *= int(x)
        sizes.append((n * BYTES[dt], f"{dt}[{dims}]", name.split(".")[0]))
    sizes.sort(reverse=True)
    seen = Counter()
    print("== top tensors ==")
    shown = 0
    for b, shp, name in sizes:
        key = (shp, name)
        seen[key] += 1
        if seen[key] > 1:
            continue
        print(f"{b/2**30:8.2f} GiB  {shp:40s} {name}")
        shown += 1
        if shown >= 25:
            break


if __name__ == "__main__":
    main(sys.argv[1], sys.argv[2], sys.argv[3] if len(sys.argv) > 3 else "single")
