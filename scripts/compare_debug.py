"""Dev harness: lockstep-compare SerialSim vs VectorSim, report first divergence."""
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core.config import SimConfig, CacheConfig
from repro.core.ref_serial import SerialSim, STAT_NAMES
from repro.core.sim import VectorSim
from repro.core.trace import resolve_trace
from repro.core import state as S


def serial_snapshot(ss: SerialSim):
    n = ss.cfg.num_nodes
    inp = np.zeros((n, 4, S.NUM_F), np.int64)
    for node in range(n):
        for p, f in enumerate(ss.inp[node]):
            if f is not None:
                inp[node, p] = [1, f.age, f.src, f.dst, f.osrc, f.typ, f.tag,
                                f.pkt, f.fid, f.nfl]
    qsize = np.array([len(q) for q in ss.sendq])
    pc = np.zeros((n, ss.cfg.pc_depth, 5), np.int64)
    for node in range(n):
        for i, (t, src, osrc, tag) in enumerate(ss.pending[node]):
            pc[node, i] = [1, t, src, osrc, tag]
    rob_counts = np.array([len(r) for r in ss.rob])
    return dict(st=ss.st.copy(), ctr=ss.ctr.copy(), tr_ptr=ss.tr_ptr.copy(),
                pend=ss.pend_addr.copy(), inp=inp, qsize=qsize, pc=pc,
                rob_counts=rob_counts,
                l1_tag=ss.l1_tag.copy(), l2_tag=ss.l2_tag.copy(),
                l1_lru=ss.l1_lru.copy(), l2_lru=ss.l2_lru.copy(),
                l1_owner=ss.l1_owner.copy(),
                l2_mig=ss.l2_mig.copy(), l2_streak=ss.l2_streak.copy(),
                dir=ss.dir_loc.copy(),
                fwd_tag=ss.fwd_tag.copy(), fwd_dst=ss.fwd_dst.copy(),
                qfid=ss.q_fid.copy(),
                stats=np.array([ss.stats[k] for k in STAT_NAMES]))


def vector_snapshot(vs: VectorSim):
    s = vs.state
    rob_counts = np.sum(np.asarray(s.rob[:, :, S.R_NFL]) > 0, axis=1)
    return dict(st=np.asarray(s.st), ctr=np.asarray(s.ctr),
                tr_ptr=np.asarray(s.tr_ptr), pend=np.asarray(s.pend_addr),
                inp=np.asarray(s.inp), qsize=np.asarray(s.q_size),
                pc=np.asarray(s.pc), rob_counts=rob_counts,
                l1_tag=np.asarray(s.l1_tag), l2_tag=np.asarray(s.l2_tag),
                l1_lru=np.asarray(s.l1_lru), l2_lru=np.asarray(s.l2_lru),
                l1_owner=np.asarray(s.l1_owner),
                l2_mig=np.asarray(s.l2_mig), l2_streak=np.asarray(s.l2_streak),
                dir=np.asarray(s.dir_loc)[:-1],
                fwd_tag=np.asarray(s.fwd_tag), fwd_dst=np.asarray(s.fwd_dst),
                qfid=np.asarray(s.q_fid),
                stats=np.asarray(s.stats))


def compare(a, b, cycle):
    for k in a:
        av, bv = np.asarray(a[k]), np.asarray(b[k])
        if av.shape != bv.shape:
            print(f"cycle {cycle}: SHAPE mismatch {k}: {av.shape} vs {bv.shape}")
            return k
        if not np.array_equal(av, bv):
            idx = np.argwhere(av != bv)
            print(f"cycle {cycle}: MISMATCH {k} at {idx[:8].tolist()}")
            for i in idx[:8]:
                print(f"   serial={av[tuple(i)]} vector={bv[tuple(i)]}")
            if k == "stats":
                for i in idx[:20]:
                    print(f"   stat {STAT_NAMES[i[0]]}: serial={av[tuple(i)]} vector={bv[tuple(i)]}")
            return k
    return None


def main(rows=4, cols=4, refs=40, seed=1, app="matmul", cycles=4000, **kw):
    cfg = SimConfig(rows=rows, cols=cols, addr_bits=14,
                    migrate_threshold=2, **kw)
    tr = resolve_trace(cfg, app, refs, seed)
    ss = SerialSim(cfg, tr)
    vs = VectorSim(cfg, tr)
    bad = compare(serial_snapshot(ss), vector_snapshot(vs), -1)
    if bad:
        return
    for cyc in range(cycles):
        ss.step()
        vs.step()
        bad = compare(serial_snapshot(ss), vector_snapshot(vs), cyc)
        if bad:
            print(f"diverged at cycle {cyc} on {bad}")
            return
        if ss.finished():
            print(f"finished identically at cycle {cyc}, "
                  f"stats match: {ss.stats['injected']} flits injected, "
                  f"{ss.stats['trap']} traps, {ss.stats['migrations']} migrations")
            return
    print(f"no divergence in {cycles} cycles (not finished)")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=4)
    ap.add_argument("--cols", type=int, default=4)
    ap.add_argument("--refs", type=int, default=40)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--app", default="matmul")
    ap.add_argument("--cycles", type=int, default=4000)
    ap.add_argument("--distdir", action="store_true")
    a = ap.parse_args()
    main(a.rows, a.cols, a.refs, a.seed, a.app, a.cycles,
         centralized_directory=not a.distdir)
