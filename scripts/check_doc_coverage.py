#!/usr/bin/env python
"""Doc-coverage gate for the public ``repro.core`` surface (tier-1).

Two checks, both cheap (imports only — no simulation):

1. every symbol a core module exports via ``__all__`` carries a
   non-trivial docstring;
2. the *named* public surface — the symbols users script against —
   documents every parameter by name (args/returns/shape conventions
   live in the docstrings; this guard keeps them from rotting when a
   signature changes).

Run directly or via ``scripts/tier1.sh``:

    PYTHONPATH=src python scripts/check_doc_coverage.py
"""
from __future__ import annotations

import importlib
import inspect
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

#: modules whose whole ``__all__`` must be documented
MODULES = [
    "repro.core",
    "repro.bench",
    "repro.core.engine",
    "repro.core.sweep",
    "repro.core.sharded",
    "repro.core.sim",
    "repro.core.config",
    "repro.core.workloads",
    "repro.core.zoo",
]

#: (module, symbol): every signature parameter must appear in the
#: docstring (class + __init__ docstrings count for classes)
NAMED_SURFACE = [
    ("repro.core", "run"),
    ("repro.core", "make_scenario"),
    ("repro.bench", "Metric"),
    ("repro.bench", "Benchmark"),
    ("repro.bench", "compare_reports"),
    ("repro.core.engine", "Scenario"),
    ("repro.core.engine", "compile_plan"),
    ("repro.core.engine", "execute_plan"),
    ("repro.core.engine", "choose_backend"),
    ("repro.core.engine", "backend_cost"),
    ("repro.core.sweep", "SweepSpec"),
    ("repro.core.sweep", "run_sweep"),
    ("repro.core.sharded", "ShardedSim"),
    ("repro.core.sharded", "run_composed"),
    ("repro.core.workloads", "resolve_trace"),
    ("repro.core.workloads", "pattern_trace"),
    ("repro.core.zoo", "ZooFamily"),
]

MIN_DOC = 40   # characters; filters out placeholder one-worders


def symbol_doc(obj) -> str:
    doc = inspect.getdoc(obj) or ""
    if inspect.isclass(obj):
        init = inspect.getdoc(obj.__init__) or ""
        if not init.startswith("Initialize self"):   # object.__init__ boilerplate
            doc += "\n" + init
    return doc


def params_of(obj):
    target = obj.__init__ if inspect.isclass(obj) else obj
    try:
        sig = inspect.signature(target)
    except (TypeError, ValueError):
        return []
    return [p for p in sig.parameters if p not in ("self", "cls")]


def main() -> int:
    errors = []
    for modname in MODULES:
        mod = importlib.import_module(modname)
        exported = getattr(mod, "__all__", None)
        if exported is None:
            errors.append(f"{modname}: missing __all__")
            continue
        for name in exported:
            obj = getattr(mod, name, None)
            if obj is None:
                errors.append(f"{modname}.{name}: in __all__ but undefined")
                continue
            if not (inspect.isclass(obj) or inspect.isroutine(obj)):
                continue   # data constants document themselves in context
            doc = symbol_doc(obj)
            if len(doc) < MIN_DOC:
                errors.append(f"{modname}.{name}: docstring missing or "
                              f"trivial ({len(doc)} chars < {MIN_DOC})")
    for modname, name in NAMED_SURFACE:
        obj = getattr(importlib.import_module(modname), name)
        doc = symbol_doc(obj)
        missing = [p for p in params_of(obj) if p not in doc]
        if missing:
            errors.append(f"{modname}.{name}: parameters not documented: "
                          f"{missing}")
    if errors:
        print("doc coverage FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    n = sum(len(getattr(importlib.import_module(m), "__all__", []))
            for m in MODULES)
    print(f"doc coverage OK ({n} exported symbols, "
          f"{len(NAMED_SURFACE)} param-checked)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
