#!/usr/bin/env python
"""Generate ``docs/cli.md`` from the ``repro.launch.simulate`` argparse tree.

    PYTHONPATH=src python scripts/gen_cli_docs.py            # rewrite
    PYTHONPATH=src python scripts/gen_cli_docs.py --check    # CI drift gate

The page is fully derived: the flag table comes from
``repro.launch.simulate.build_parser()`` (so help strings are the single
source of truth) and the worked examples live in this generator.  CI runs
``--check`` and fails when the committed page drifts from the parser.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

DOC_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        os.pardir, "docs", "cli.md")

PROLOG = """\
# `repro.launch.simulate` — command-line reference

> **Generated file — do not edit.**  Regenerate with
> `PYTHONPATH=src python scripts/gen_cli_docs.py` (CI fails on drift).

The launcher is one entry point with five modes.  All but `--serial`
route through the execution-plan layer (`repro.core.engine`): scenarios
are bucketed by structural config, each bucket compiles once, and a cost
model picks the `sweep` / `sharded` / `composed` backend per bucket
(`docs/architecture.md` has the decision table).

## Modes

```sh
# solo run (a plan of one scenario)
PYTHONPATH=src python -m repro.launch.simulate --rows 16 --cols 16 \\
    --app matmul --refs 100

# golden-model serial simulator (no planner)
PYTHONPATH=src python -m repro.launch.simulate --serial --rows 8 --cols 8

# batched sweep: the --apps x --seeds cross-product as ONE compiled program
PYTHONPATH=src python -m repro.launch.simulate --rows 16 --cols 16 \\
    --sweep --apps matmul,equake,mgrid --seeds 0,1 --refs 50

# heterogeneous plan from a manifest
PYTHONPATH=src python -m repro.launch.simulate --plan manifest.json

# a registered scenario-zoo family (repro.core.zoo; `--zoo list` enumerates)
PYTHONPATH=src python -m repro.launch.simulate --zoo patterns-small
```

`--backend {auto,sweep,sharded,composed}` pins the planner's backend in
any planner mode; a structurally impossible pin degrades to `sweep` with
an explanatory `note` in the output instead of failing.

## Workload sources

`--app` (and the APP field of manifests, `--apps`, zoo families) is a
**traffic-generator registry** spec — `name` or `name:key=val,...`
(`repro.core.workloads`; bare values fill the generator's positional
slots, so `loop:matmul` == `loop:app=matmul`).  Patterns realize their
destination pattern through distributed-directory homes — pair them
with a distributed directory (the zoo families do).  The registry
(generated — new generators appear here automatically):

```text
%SOURCE_HELP%
```

## `--plan` manifests

`--plan` accepts three spellings of the same thing.

**1. Compact grammar** — `ROWSxCOLS[:APP][:SEED[:REFS]]` items joined
with `;` or `,` (APP defaults to `matmul`, SEED to `0`, REFS to `200`).
APP may be any source spec, including parameterized ones — up to two
trailing *integer* fields parse as SEED/REFS, so spell source parameters
`key=val`:

```sh
PYTHONPATH=src python -m repro.launch.simulate \\
    --plan '8x8:matmul:0:50;8x8:equake:1:50;16x16:equake:0:50'
PYTHONPATH=src python -m repro.launch.simulate \\
    --plan '8x8:hotspot:frac=0.8,hot=2:0:50;8x8:transpose:rate=0.5'
```

**2. Inline JSON** — an object with an optional `base` (any `SimConfig`
field, shared by every scenario) and a `scenarios` list (workload keys
`app`/`seed`/`refs_per_core` plus per-scenario `SimConfig` overrides —
structural overrides split compile buckets, policy knobs do not):

```sh
PYTHONPATH=src python -m repro.launch.simulate --plan '{
  "base": {"centralized_directory": false},
  "scenarios": [
    {"rows": 8,  "cols": 8,  "app": "matmul", "seed": 0, "refs_per_core": 50},
    {"rows": 16, "cols": 16, "app": "equake", "seed": 1,
     "migration_enabled": false}]}'
```

**3. A path to a JSON file** holding the same object (or a bare
scenario list).

Output for `--sweep`/`--plan` is a JSON payload with the plan summary
(`plan.buckets[*].backend`, the composed backend's device `grid`, any
degradation `note`) and one stats object per scenario in input order.

## Flags
"""


def flag_table() -> str:
    from repro.launch.simulate import build_parser
    ap = build_parser()
    rows = ["| flag | type | default | description |",
            "|---|---|---|---|"]
    for a in ap._actions:
        if isinstance(a, argparse._HelpAction):
            continue
        flag = ", ".join(f"`{s}`" for s in a.option_strings)
        if a.choices:
            typ = "{" + ",".join(str(c) for c in a.choices) + "}"
        elif isinstance(a, argparse._StoreTrueAction):
            typ = "flag"
        elif a.type is int:
            typ = "int"
        else:
            typ = "str"
        default = ("" if a.default is None or a.default is False
                   or a.default is argparse.SUPPRESS
                   else f"`{a.default}`")
        help_text = " ".join((a.help or "").split())
        rows.append(f"| {flag} | {typ} | {default} | {help_text} |")
    return "\n".join(rows) + "\n"


def render() -> str:
    from repro.core.workloads import source_help
    return PROLOG.replace("%SOURCE_HELP%", source_help()) + flag_table()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) if docs/cli.md differs from the "
                         "argparse tree instead of rewriting it")
    args = ap.parse_args()
    text = render()
    if args.check:
        try:
            with open(DOC_PATH) as f:
                on_disk = f.read()
        except FileNotFoundError:
            print(f"gen_cli_docs: {DOC_PATH} missing", file=sys.stderr)
            return 1
        if on_disk != text:
            print("gen_cli_docs: docs/cli.md drifted from the argparse "
                  "tree; run: PYTHONPATH=src python scripts/gen_cli_docs.py",
                  file=sys.stderr)
            return 1
        print("gen_cli_docs: docs/cli.md is current")
        return 0
    os.makedirs(os.path.dirname(DOC_PATH), exist_ok=True)
    with open(DOC_PATH, "w") as f:
        f.write(text)
    print(f"gen_cli_docs: wrote {DOC_PATH}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
