#!/usr/bin/env bash
# Tier-1 gate: the full test suite on CPU, importable with zero network
# access (optional deps like `hypothesis` are shimmed by tests/conftest.py,
# so a missing package must never break *collection*).
#
# The default collection includes the execution-plan layer's modules —
# tests/test_engine.py (planner: bucketing, cost model, --plan CLI),
# tests/test_trace_vec.py (vectorized trace synthesis parity) and
# tests/test_detectors.py (livelock/saturation monitors) — and this guard
# fails fast if any of them stops being collected.
set -euo pipefail
cd "$(dirname "$0")/.."
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
for mod in tests/test_engine.py tests/test_trace_vec.py tests/test_detectors.py tests/test_composed.py tests/test_workloads.py tests/test_zoo.py tests/test_bench.py; do
  [[ -f "$mod" ]] || { echo "tier1: missing $mod" >&2; exit 1; }
done
# docs gates: public-surface docstrings and the generated CLI page
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python scripts/check_doc_coverage.py
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python scripts/gen_cli_docs.py --check
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
