#!/usr/bin/env bash
# Tier-1 gate: the full test suite on CPU, importable with zero network
# access (optional deps like `hypothesis` are shimmed by tests/conftest.py,
# so a missing package must never break *collection*).
set -euo pipefail
cd "$(dirname "$0")/.."
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
