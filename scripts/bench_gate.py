#!/usr/bin/env python
"""Perf regression gate: diff fresh BENCH reports against committed baselines.

    # CI / local check: run the gated smoke tier, diff, trend, exit 1 on
    # any out-of-slack regression
    PYTHONPATH=src python scripts/bench_gate.py --smoke

    # compare pre-generated reports instead of running benchmarks
    PYTHONPATH=src python scripts/bench_gate.py --fresh-dir results

    # refresh the committed baselines from a fresh smoke run
    PYTHONPATH=src python scripts/bench_gate.py --smoke --update

Baselines are the repo-root ``BENCH_<area>.json`` files (areas:
``benchmarks/run.py`` ``GATED_AREAS``).  Comparison semantics —
direction awareness, per-metric slack, vanished/new metrics — live in
:mod:`repro.bench.gate`; this script only orchestrates subprocesses,
git-history trends and exit codes.  See ``docs/benchmarks.md``.
"""
from __future__ import annotations

import argparse
import importlib.util
import os
import shutil
import subprocess
import sys
import tempfile
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.bench import (BenchReport, compare_reports, gate_passes,   # noqa: E402
                         render_findings, render_trend)


def _harness():
    """The benchmark harness module (single source of areas/files)."""
    spec = importlib.util.spec_from_file_location(
        "bench_run", REPO_ROOT / "benchmarks" / "run.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def baseline_path(area: str, baseline_dir: Path) -> Path:
    return baseline_dir / f"BENCH_{area}.json"


def git_history(area: str, baseline_dir: Path, limit: int = 6):
    """Past committed versions of the area baseline, oldest first, as
    ``(short_rev, BenchReport)`` pairs.  Best-effort: returns ``[]`` when
    git (or the history) is unavailable."""
    rel = os.path.relpath(baseline_path(area, baseline_dir), REPO_ROOT)
    try:
        revs = subprocess.run(
            ["git", "log", "--format=%h", "-n", str(limit), "--", rel],
            cwd=REPO_ROOT, capture_output=True, text=True, timeout=30,
        ).stdout.split()
        out = []
        for rev in reversed(revs):
            show = subprocess.run(["git", "show", f"{rev}:{rel}"],
                                  cwd=REPO_ROOT, capture_output=True,
                                  text=True, timeout=30)
            if show.returncode == 0:
                out.append((rev, BenchReport.from_json(show.stdout)))
        return out
    except Exception:
        return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff fresh benchmark reports against the committed "
                    "BENCH_<area>.json baselines.")
    ap.add_argument("--smoke", action="store_true",
                    help="run the gated benchmarks at their smoke tier "
                         "into a temp dir, then diff (the CI mode)")
    ap.add_argument("--fresh-dir", default=None, metavar="DIR",
                    help="diff pre-generated BENCH_<area>.json reports "
                         "from DIR instead of running benchmarks")
    ap.add_argument("--baseline-dir", default=str(REPO_ROOT), metavar="DIR",
                    help="where the committed baselines live "
                         "(default: repo root)")
    ap.add_argument("--areas", default=None,
                    help="comma list of areas to gate (default: the "
                         "harness GATED_AREAS)")
    ap.add_argument("--slack-scale", type=float, default=1.0,
                    help="multiply every baseline slack (loosen a noisy "
                         "host with e.g. 2.0 without editing baselines)")
    ap.add_argument("--update", action="store_true",
                    help="copy the fresh reports over the baselines "
                         "instead of failing on drift (refresh workflow)")
    ap.add_argument("--no-trend", action="store_true",
                    help="skip the git-history trend table")
    args = ap.parse_args(argv)

    if bool(args.smoke) == bool(args.fresh_dir):
        ap.error("choose exactly one of --smoke (run benchmarks) or "
                 "--fresh-dir DIR (pre-generated reports)")

    harness = _harness()
    areas = [a.strip() for a in args.areas.split(",")] if args.areas \
        else list(harness.GATED_AREAS)
    baseline_dir = Path(args.baseline_dir)

    tmp = None
    if args.smoke:
        tmp = tempfile.mkdtemp(prefix="bench_gate_")
        fresh_dir = Path(tmp)
        for area in areas:
            print(f"== running {area} (smoke) ==", flush=True)
            rc = harness.invoke(area, smoke=True,
                                out=str(fresh_dir / f"BENCH_{area}.json"))
            if rc:
                print(f"bench_gate: {area} benchmark FAILED (exit {rc})",
                      file=sys.stderr)
                return rc
    else:
        fresh_dir = Path(args.fresh_dir)

    failed = False
    for area in areas:
        fresh_path = fresh_dir / f"BENCH_{area}.json"
        base_path = baseline_path(area, baseline_dir)
        if not fresh_path.exists():
            print(f"bench_gate: missing fresh report {fresh_path}",
                  file=sys.stderr)
            failed = True
            continue
        if not base_path.exists():
            if args.update:
                shutil.copyfile(fresh_path, base_path)
                print(f"{area}: no baseline yet — seeded {base_path}")
                continue
            print(f"bench_gate: missing baseline {base_path} "
                  f"(seed it with --update)", file=sys.stderr)
            failed = True
            continue
        base = BenchReport.read(str(base_path))
        fresh = BenchReport.read(str(fresh_path))
        findings = compare_reports(base, fresh,
                                   slack_scale=args.slack_scale)
        print()
        print(render_findings(area, findings))
        if not args.no_trend:
            history = git_history(area, baseline_dir)
            print(render_trend(history + [("fresh", fresh)]))
        if args.update:
            shutil.copyfile(fresh_path, base_path)
            print(f"{area}: baseline refreshed at {base_path}")
        elif not gate_passes(findings):
            failed = True

    if tmp:
        shutil.rmtree(tmp, ignore_errors=True)
    if failed:
        print("\nbench gate: FAIL (out-of-slack regression or missing "
              "report — see above; refresh intentionally with --update)",
              file=sys.stderr)
        return 1
    print("\nbench gate: OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
